"""Vamana / DiskANN graph index (paper §2.2, §5–§7) — JAX-accelerated.

TPU adaptation (DESIGN.md §2): the graph lives as a **dense padded adjacency**
``int32 (N, R)`` (−1 padding) instead of SSD-resident varint lists; beam
search is a fully-jittable ``lax.while_loop`` over a fixed-size candidate
pool, so the probe path can run *on device* inside a shard_map'd serving
step.  Graph construction keeps DiskANN's batch-parallel structure: batched
beam searches + batched robust-prune (both jit'd), with only the variable-
degree reverse-edge scatter on host.

Entry points:
- :func:`build_vamana`      — full build (random init + 2 refinement passes)
- :meth:`VamanaGraph.search`        — batched beam search (full precision)
- :meth:`VamanaGraph.search_pq`     — beam search with PQ ADC distances and
  exact rerank of the pool (the paper's Stage-A probe)
- :meth:`VamanaGraph.search_masked` — predicate-aware beam search: masked
  nodes are traversed for connectivity but never admitted to the result set
  (the filtered-DiskANN move behind the ``MaskedBeam`` plan op)
- :meth:`VamanaGraph.insert_batch`  — greedy insert (§7.2 refresh)
- :meth:`VamanaGraph.tombstone`     — lazy deletes (§7.3)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pq import PQCodebook, build_luts, encode


@dataclass
class VamanaParams:
    R: int = 64  # max degree
    L: int = 100  # beam width / pool size
    alpha: float = 1.2  # RNG pruning slack
    metric: str = "l2"  # l2 | ip

    def to_props(self) -> dict:
        return {"R": str(self.R), "L": str(self.L), "alpha": str(self.alpha), "metric": self.metric}


# ---------------------------------------------------------------------------
# jit'd primitives.  All take padded fixed shapes; `n_valid` bounds real ids.
# ---------------------------------------------------------------------------


def _pair_dist(q: jnp.ndarray, v: jnp.ndarray, metric: str) -> jnp.ndarray:
    """q: (..., D), v: (..., D) -> (...)"""
    if metric == "ip":
        return -jnp.sum(q * v, axis=-1)
    diff = q - v
    return jnp.sum(diff * diff, axis=-1)


def _dedupe_sorted_by_id(ids, dists, expanded):
    """Mark duplicate ids invalid.  Inputs already sorted by id asc with
    expanded entries first within a run (so the surviving copy keeps its
    expansion status)."""
    dup = jnp.concatenate(
        [jnp.zeros_like(ids[:, :1], dtype=bool), ids[:, 1:] == ids[:, :-1]], axis=1
    )
    dists = jnp.where(dup, jnp.inf, dists)
    expanded = jnp.where(dup, True, expanded)  # never expand a dup
    return ids, dists, expanded


@functools.partial(jax.jit, static_argnames=("L", "max_iters", "metric", "use_pq"))
def _beam_search(
    vectors: jnp.ndarray,  # (cap, D) f32   (or PQ codes (cap, m) int32 if use_pq)
    adjacency: jnp.ndarray,  # (cap, R) int32, -1 pad
    n_valid: jnp.ndarray,  # () int32
    entry: jnp.ndarray,  # () int32
    queries: jnp.ndarray,  # (B, D) f32     (or LUTs (B, m, K) f32 if use_pq)
    L: int,
    max_iters: int,
    metric: str,
    use_pq: bool,
):
    """Batched greedy beam search.

    Returns (pool_ids (B,L), pool_dists (B,L), visited_ids (B,max_iters),
    visited_dists (B,max_iters)).  Invalid slots: id == cap, dist == +inf.
    """
    cap = vectors.shape[0]
    B = queries.shape[0]
    INF = jnp.float32(jnp.inf)

    def dist_to(ids: jnp.ndarray) -> jnp.ndarray:  # ids (B, K) -> (B, K)
        safe = jnp.clip(ids, 0, cap - 1)
        if use_pq:
            codes = vectors[safe]  # (B, K, m) int32
            # luts: (B, m, Kcode); gather -> (B, m, K)
            g = jnp.take_along_axis(queries, codes.transpose(0, 2, 1), axis=2)
            d = jnp.sum(g, axis=1)
        else:
            v = vectors[safe]  # (B, K, D)
            d = _pair_dist(queries[:, None, :], v, metric)
        return jnp.where(ids < n_valid, d, INF)

    # multi-entry seeding: the medoid plus three strided nodes.  Costs three
    # extra expansions but makes search robust to weakly-connected regions
    # (single-pass builds on clustered data can leave islands the medoid
    # alone never reaches).
    n_seeds = min(4, L)
    strides = jnp.arange(n_seeds, dtype=jnp.int32)
    seeds = jnp.where(
        strides == 0, entry, (strides * (n_valid // jnp.int32(n_seeds))) % jnp.maximum(n_valid, 1)
    )
    pool_ids = jnp.full((B, L), cap, jnp.int32).at[:, :n_seeds].set(
        jnp.broadcast_to(seeds, (B, n_seeds))
    )
    d0 = dist_to(pool_ids[:, :n_seeds])
    pool_dists = jnp.full((B, L), INF).at[:, :n_seeds].set(d0)
    pool_exp = jnp.ones((B, L), bool).at[:, :n_seeds].set(False)
    visited_ids = jnp.full((B, max_iters), cap, jnp.int32)
    visited_dists = jnp.full((B, max_iters), INF)

    def has_frontier(state):
        _, dists, exp, *_ = state
        return jnp.any(~exp & jnp.isfinite(dists))

    def cond(state):
        return has_frontier(state) & (state[-1] < max_iters)

    def body(state):
        ids, dists, exp, vis_ids, vis_dists, it = state
        frontier = jnp.where(~exp & jnp.isfinite(dists), dists, INF)
        best = jnp.argmin(frontier, axis=1)  # (B,)
        row = jnp.arange(B)
        best_id = ids[row, best]
        best_dist = dists[row, best]
        active = jnp.isfinite(frontier[row, best])  # row still has frontier
        exp = exp.at[row, best].set(True)
        vis_ids = vis_ids.at[row, it].set(jnp.where(active, best_id, cap))
        vis_dists = vis_dists.at[row, it].set(jnp.where(active, best_dist, INF))
        nbrs = adjacency[jnp.clip(best_id, 0, cap - 1)]  # (B, R)
        nbrs = jnp.where((nbrs >= 0) & active[:, None], nbrs, cap)
        nd = dist_to(nbrs)
        # merge pool + neighbors
        cat_ids = jnp.concatenate([ids, nbrs], axis=1)
        cat_dists = jnp.concatenate([dists, nd], axis=1)
        cat_exp = jnp.concatenate([exp, jnp.zeros_like(nbrs, bool)], axis=1)
        # sort by (id asc, expanded first) to dedupe: key = id*2 + (1-expanded)
        key = cat_ids * 2 + (1 - cat_exp.astype(jnp.int32))
        order = jnp.argsort(key, axis=1)
        cat_ids = jnp.take_along_axis(cat_ids, order, axis=1)
        cat_dists = jnp.take_along_axis(cat_dists, order, axis=1)
        cat_exp = jnp.take_along_axis(cat_exp, order, axis=1)
        cat_ids, cat_dists, cat_exp = _dedupe_sorted_by_id(cat_ids, cat_dists, cat_exp)
        # keep top-L by distance
        order = jnp.argsort(cat_dists, axis=1)[:, :L]
        ids = jnp.take_along_axis(cat_ids, order, axis=1)
        dists = jnp.take_along_axis(cat_dists, order, axis=1)
        exp = jnp.take_along_axis(cat_exp, order, axis=1)
        return ids, dists, exp, vis_ids, vis_dists, it + 1

    state = (pool_ids, pool_dists, pool_exp, visited_ids, visited_dists, jnp.int32(0))
    ids, dists, _exp, vis_ids, vis_dists, _ = jax.lax.while_loop(cond, body, state)
    return ids, dists, vis_ids, vis_dists


@functools.partial(
    jax.jit, static_argnames=("L", "k_res", "max_iters", "metric", "use_pq")
)
def _masked_beam_search(
    vectors: jnp.ndarray,  # (cap, D) f32   (or PQ codes (cap, m) int32 if use_pq)
    adjacency: jnp.ndarray,  # (cap, R) int32, -1 pad
    n_valid: jnp.ndarray,  # () int32
    entry: jnp.ndarray,  # () int32
    queries: jnp.ndarray,  # (B, D) f32     (or LUTs (B, m, K) f32 if use_pq)
    mask_unique: jnp.ndarray,  # (m, cap) bool — True = admissible
    mask_idx: jnp.ndarray,  # (B,) int32 — query row -> mask row
    L: int,
    k_res: int,
    max_iters: int,
    metric: str,
    use_pq: bool,
):
    """Predicate-aware batched beam search (the filtered-DiskANN traversal).

    The frontier expands exactly like :func:`_beam_search` — masked nodes
    keep their connectivity role, their distances steer the pool — and every
    (id, dist) the traversal evaluates is buffered; after the loop ONE
    mask-gated admit pass (neutralize inadmissible, dedupe by id, top-k_res
    by distance) builds the admitted result set.  Hoisting the admit out of
    the loop matters: an in-loop accumulator costs two extra argsorts per
    iteration, which is what let the unmasked postfilter beam win the
    paired bench timing.  The admitted SET is identical either way — an
    in-loop accumulator would only ever see these same candidates.  The
    mask ships dedup'd: ``mask_unique`` holds the
    distinct admissibility rows, ``mask_idx`` maps each query to its row
    (the PR 5 dedup-then-broadcast shape — the (B, cap) plane is expanded by
    gather on device, never materialized on host).

    Returns (res_ids (B, k_res), res_dists (B, k_res), vis_ids
    (B, max_iters)).  Result rows ascend by distance; slots the traversal
    could not fill hold (id == cap, dist == +inf).
    """
    cap = vectors.shape[0]
    B = queries.shape[0]
    INF = jnp.float32(jnp.inf)

    def dist_to(ids: jnp.ndarray) -> jnp.ndarray:  # ids (B, K) -> (B, K)
        safe = jnp.clip(ids, 0, cap - 1)
        if use_pq:
            codes = vectors[safe]  # (B, K, m) int32
            g = jnp.take_along_axis(queries, codes.transpose(0, 2, 1), axis=2)
            d = jnp.sum(g, axis=1)
        else:
            v = vectors[safe]  # (B, K, D)
            d = _pair_dist(queries[:, None, :], v, metric)
        return jnp.where(ids < n_valid, d, INF)

    R = adjacency.shape[1]

    n_seeds = min(4, L)
    strides = jnp.arange(n_seeds, dtype=jnp.int32)
    seeds = jnp.where(
        strides == 0, entry, (strides * (n_valid // jnp.int32(n_seeds))) % jnp.maximum(n_valid, 1)
    )
    pool_ids = jnp.full((B, L), cap, jnp.int32).at[:, :n_seeds].set(
        jnp.broadcast_to(seeds, (B, n_seeds))
    )
    d0 = dist_to(pool_ids[:, :n_seeds])
    pool_dists = jnp.full((B, L), INF).at[:, :n_seeds].set(d0)
    pool_exp = jnp.ones((B, L), bool).at[:, :n_seeds].set(False)
    visited_ids = jnp.full((B, max_iters), cap, jnp.int32)
    # every (id, dist) the traversal evaluates, buffered for the single
    # post-loop admit pass: one (B, R) slab per iteration
    cand_ids = jnp.full((B, max_iters, R), cap, jnp.int32)
    cand_dists = jnp.full((B, max_iters, R), INF)

    def cond(state):
        _, dists, exp, _, _, _, it = state
        return jnp.any(~exp & jnp.isfinite(dists)) & (it < max_iters)

    def body(state):
        ids, dists, exp, vis_ids, c_ids, c_dists, it = state
        frontier = jnp.where(~exp & jnp.isfinite(dists), dists, INF)
        best = jnp.argmin(frontier, axis=1)  # (B,)
        row = jnp.arange(B)
        best_id = ids[row, best]
        active = jnp.isfinite(frontier[row, best])
        exp = exp.at[row, best].set(True)
        vis_ids = vis_ids.at[row, it].set(jnp.where(active, best_id, cap))
        nbrs = adjacency[jnp.clip(best_id, 0, cap - 1)]  # (B, R)
        nbrs = jnp.where((nbrs >= 0) & active[:, None], nbrs, cap)
        nd = dist_to(nbrs)
        c_ids = c_ids.at[:, it, :].set(nbrs)
        c_dists = c_dists.at[:, it, :].set(nd)
        cat_ids = jnp.concatenate([ids, nbrs], axis=1)
        cat_dists = jnp.concatenate([dists, nd], axis=1)
        cat_exp = jnp.concatenate([exp, jnp.zeros_like(nbrs, bool)], axis=1)
        key = cat_ids * 2 + (1 - cat_exp.astype(jnp.int32))
        order = jnp.argsort(key, axis=1)
        cat_ids = jnp.take_along_axis(cat_ids, order, axis=1)
        cat_dists = jnp.take_along_axis(cat_dists, order, axis=1)
        cat_exp = jnp.take_along_axis(cat_exp, order, axis=1)
        cat_ids, cat_dists, cat_exp = _dedupe_sorted_by_id(cat_ids, cat_dists, cat_exp)
        order = jnp.argsort(cat_dists, axis=1)[:, :L]
        ids = jnp.take_along_axis(cat_ids, order, axis=1)
        dists = jnp.take_along_axis(cat_dists, order, axis=1)
        exp = jnp.take_along_axis(cat_exp, order, axis=1)
        return ids, dists, exp, vis_ids, c_ids, c_dists, it + 1

    state = (
        pool_ids,
        pool_dists,
        pool_exp,
        visited_ids,
        cand_ids,
        cand_dists,
        jnp.int32(0),
    )
    _, _, _, vis_ids, cand_ids, cand_dists, _ = jax.lax.while_loop(cond, body, state)

    # the ONE admit pass: seeds ∪ every buffered neighbor offer, gated by the
    # query's mask row, deduped by id (same id ⇒ same distance, so either
    # copy may survive), top-k_res by distance.  Inadmissible candidates are
    # neutralized to (cap, +inf) so they can never displace an admitted node.
    all_ids = jnp.concatenate(
        [
            jnp.broadcast_to(seeds, (B, n_seeds)),
            cand_ids.reshape(B, max_iters * R),
        ],
        axis=1,
    )
    all_d = jnp.concatenate([d0, cand_dists.reshape(B, max_iters * R)], axis=1)
    if all_ids.shape[1] < k_res:  # static: keep the output width at k_res
        pad = k_res - all_ids.shape[1]
        all_ids = jnp.pad(all_ids, ((0, 0), (0, pad)), constant_values=cap)
        all_d = jnp.pad(all_d, ((0, 0), (0, pad)), constant_values=jnp.inf)
    safe = jnp.clip(all_ids, 0, cap - 1)
    ok = mask_unique[mask_idx[:, None], safe] & (all_ids < n_valid)
    all_ids = jnp.where(ok, all_ids, cap)
    all_d = jnp.where(ok, all_d, INF)
    order = jnp.argsort(all_ids, axis=1)
    s_ids = jnp.take_along_axis(all_ids, order, axis=1)
    s_d = jnp.take_along_axis(all_d, order, axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(s_ids[:, :1], bool), s_ids[:, 1:] == s_ids[:, :-1]],
        axis=1,
    )
    s_d = jnp.where(dup, INF, s_d)
    order = jnp.argsort(s_d, axis=1)[:, :k_res]
    res_ids = jnp.take_along_axis(s_ids, order, axis=1)
    res_dists = jnp.take_along_axis(s_d, order, axis=1)
    return res_ids, res_dists, vis_ids


@functools.partial(jax.jit, static_argnames=("R", "alpha", "metric"))
def _robust_prune(
    vectors: jnp.ndarray,  # (cap, D)
    p_vecs: jnp.ndarray,  # (B, D) the points being pruned
    cand_ids: jnp.ndarray,  # (B, C) candidate ids (cap = invalid)
    n_valid: jnp.ndarray,
    R: int,
    alpha: float,
    metric: str,
):
    """Vectorized α-RNG robust prune.  Returns (B, R) int32, -1 padded."""
    cap, D = vectors.shape
    B, C = cand_ids.shape
    safe = jnp.clip(cand_ids, 0, cap - 1)
    cand_vecs = vectors[safe]  # (B, C, D)
    valid = cand_ids < n_valid
    d_p = jnp.where(valid, _pair_dist(p_vecs[:, None, :], cand_vecs, metric), jnp.inf)
    # dedupe identical ids: sort by id, invalidate repeats
    order = jnp.argsort(cand_ids, axis=1)
    s_ids = jnp.take_along_axis(cand_ids, order, axis=1)
    s_dp = jnp.take_along_axis(d_p, order, axis=1)
    s_vecs = jnp.take_along_axis(cand_vecs, order[:, :, None], axis=1)
    dup = jnp.concatenate(
        [jnp.zeros_like(s_ids[:, :1], bool), s_ids[:, 1:] == s_ids[:, :-1]], axis=1
    )
    s_dp = jnp.where(dup, jnp.inf, s_dp)
    alive = jnp.isfinite(s_dp)

    result = jnp.full((B, R), -1, jnp.int32)

    def body(step, carry):
        alive, result = carry
        masked = jnp.where(alive, s_dp, jnp.inf)
        pick = jnp.argmin(masked, axis=1)  # (B,)
        row = jnp.arange(B)
        ok = jnp.isfinite(masked[row, pick])
        pick_id = s_ids[row, pick]
        result = result.at[:, step].set(jnp.where(ok, pick_id, -1))
        pvec = s_vecs[row, pick]  # (B, D)
        d_star = _pair_dist(pvec[:, None, :], s_vecs, metric)  # (B, C)
        kill = alpha * d_star <= s_dp  # removes pick itself (d_star=0)
        alive = alive & ~kill & ok[:, None]
        return alive, result

    _, result = jax.lax.fori_loop(0, R, body, (alive, result))
    return result


# ---------------------------------------------------------------------------
# Graph object (host-resident arrays; device work via the jit'd primitives)
# ---------------------------------------------------------------------------


def _round_capacity(n: int) -> int:
    cap = 1024
    while cap < n:
        cap *= 2
    return cap


@dataclass
class VamanaGraph:
    vectors: np.ndarray  # (cap, D) f32; rows >= n are padding
    adjacency: np.ndarray  # (cap, R) int32, -1 pad
    n: int
    medoid: int
    params: VamanaParams
    tombstones: np.ndarray = field(default=None)  # (cap,) bool
    pq: Optional[PQCodebook] = None
    pq_codes: Optional[np.ndarray] = None  # (cap, m) uint8

    def __post_init__(self):
        if self.tombstones is None:
            self.tombstones = np.zeros(self.vectors.shape[0], dtype=bool)

    # -- stats ---------------------------------------------------------------
    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def num_live(self) -> int:
        return int(self.n - self.tombstones[: self.n].sum())

    @property
    def tombstone_ratio(self) -> float:
        return float(self.tombstones[: self.n].sum() / max(self.n, 1))

    def degrees(self) -> np.ndarray:
        return (self.adjacency[: self.n] >= 0).sum(axis=1)

    # -- search ---------------------------------------------------------------
    def search(
        self,
        queries: np.ndarray,
        k: int,
        L: Optional[int] = None,
        batch: int = 64,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Full-precision beam search.  Returns (dists (Q,k), ids (Q,k));
        tombstoned nodes traversed but filtered (paper §7.3)."""
        L = max(L or self.params.L, k)
        queries = np.ascontiguousarray(queries, dtype=np.float32)
        out_d = np.empty((queries.shape[0], k), np.float32)
        out_i = np.empty((queries.shape[0], k), np.int64)
        max_iters = int(1.3 * L) + 8
        for s in range(0, queries.shape[0], batch):
            q = queries[s : s + batch]
            pad = batch - q.shape[0]
            qb = np.pad(q, ((0, pad), (0, 0))) if pad else q
            ids, dists, _, _ = _beam_search(
                jnp.asarray(self.vectors),
                jnp.asarray(self.adjacency),
                jnp.int32(self.n),
                jnp.int32(self.medoid),
                jnp.asarray(qb),
                L,
                max_iters,
                self.params.metric,
                False,
            )
            ids_np = np.asarray(ids)
            dists_np = np.asarray(dists)
            # lazy-tombstone filter
            ts = self.tombstones[np.clip(ids_np, 0, self.vectors.shape[0] - 1)]
            dists_np = np.where(ts | (ids_np >= self.n), np.inf, dists_np)
            order = np.argsort(dists_np, axis=1)[:, :k]
            d = np.take_along_axis(dists_np, order, axis=1)
            i = np.take_along_axis(ids_np, order, axis=1)
            out_d[s : s + q.shape[0]] = d[: q.shape[0]]
            out_i[s : s + q.shape[0]] = i[: q.shape[0]]
        return out_d, out_i

    def search_pq(
        self,
        queries: np.ndarray,
        k: int,
        L: Optional[int] = None,
        rerank: bool = True,
        batch: int = 64,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Stage-A probe: PQ-approximate traversal + full-precision rerank of
        the candidate pool (paper §6)."""
        if self.pq is None or self.pq_codes is None:
            raise ValueError("graph has no PQ data; call attach_pq()")
        L = max(L or self.params.L, k)
        queries = np.ascontiguousarray(queries, dtype=np.float32)
        out_d = np.empty((queries.shape[0], k), np.float32)
        out_i = np.empty((queries.shape[0], k), np.int64)
        max_iters = int(1.3 * L) + 8
        codes_j = jnp.asarray(self.pq_codes.astype(np.int32))
        for s in range(0, queries.shape[0], batch):
            q = queries[s : s + batch]
            pad = batch - q.shape[0]
            qb = np.pad(q, ((0, pad), (0, 0))) if pad else q
            luts = build_luts(self.pq, qb)  # (B, m, K)
            ids, dists, vis_ids, _vis_d = _beam_search(
                codes_j,
                jnp.asarray(self.adjacency),
                jnp.int32(self.n),
                jnp.int32(self.medoid),
                luts,
                L,
                max_iters,
                self.params.metric,
                True,
            )
            ids_np = np.asarray(ids)
            dists_np = np.asarray(dists)
            if rerank:
                # DiskANN-style rerank: every *visited* node's full vector is
                # already paged in during traversal, so the exact rerank runs
                # over pool ∪ visited, not just the final PQ-ranked pool —
                # this is what keeps recall high when PQ noise exceeds the
                # within-cluster distance gaps.  Duplicates, out-of-range ids
                # and tombstones all fold to the pid=-1 sentinel; the
                # gather-rerank kernel (kernels/rerank.py) scores the rest
                # on-device — no (B, C, D) host gather.
                from repro.kernels import device_cache, ops

                cand = np.concatenate([ids_np, np.asarray(vis_ids)], axis=1)
                sort_idx = np.argsort(cand, axis=1, kind="stable")
                sorted_ids = np.take_along_axis(cand, sort_idx, axis=1)
                dup = np.concatenate(
                    [
                        np.zeros((cand.shape[0], 1), bool),
                        sorted_ids[:, 1:] == sorted_ids[:, :-1],
                    ],
                    axis=1,
                )
                safe = np.clip(sorted_ids, 0, self.vectors.shape[0] - 1)
                bad = dup | (sorted_ids >= self.n) | self.tombstones[safe]
                pids = np.where(bad, -1, sorted_ids).astype(np.int32)
                rd, ri = ops.gather_rerank(
                    jnp.asarray(qb),
                    device_cache.device_vectors(self),
                    jnp.asarray(pids),
                    k,
                    metric=self.params.metric,
                    backend="auto",
                )
                out_d[s : s + q.shape[0]] = np.asarray(rd)[: q.shape[0]]
                out_i[s : s + q.shape[0]] = np.asarray(ri, np.int64)[: q.shape[0]]
                continue
            ts = self.tombstones[np.clip(ids_np, 0, self.vectors.shape[0] - 1)]
            dists_np = np.where(ts | (ids_np >= self.n), np.inf, dists_np)
            order = np.argsort(dists_np, axis=1)[:, :k]
            out_d[s : s + q.shape[0]] = np.take_along_axis(dists_np, order, axis=1)[: q.shape[0]]
            out_i[s : s + q.shape[0]] = np.take_along_axis(ids_np, order, axis=1)[: q.shape[0]]
        return out_d, out_i

    def search_masked(
        self,
        queries: np.ndarray,
        k: int,
        unique_masks: np.ndarray,
        mask_idx: Optional[np.ndarray] = None,
        L: Optional[int] = None,
        batch: int = 64,
        use_pq: bool = False,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Predicate-aware beam search (the filtered-DiskANN traversal).

        The traversal expands *through* masked nodes — connectivity is never
        lost to the predicate — but only mask-passing nodes are admitted to
        the returned top-``k``.  ``unique_masks`` is ``(m, n)`` bool over
        graph ids (True = admissible; the caller folds tombstones in —
        admissibility means *predicate AND NOT tombstoned*); ``mask_idx``
        maps each query to its mask row (default: all queries share row 0).
        With ``use_pq`` the traversal runs on ADC distances and the admitted
        pool ∪ admissible visited nodes get a full-precision host rerank.

        Unlike :meth:`search`, ``L`` is NOT floored at ``k``: the admitted
        result set is built from every neighbor the traversal evaluates
        (not from the final pool), so a wide admit target ``k`` rides a
        beam of ordinary depth.  Flooring the depth at the planner-widened
        ``k`` would make the masked traversal as expensive as the
        1/frac-deepened postfilter pool it exists to beat.

        Returns (dists (Q, k), ids (Q, k)), each row ascending; slots the
        traversal could not fill hold ``(+inf, -1)`` — the masked-op
        sentinel contract, so callers detect under-delivery and fall back to
        the exact masked scan.
        """
        k = int(k)
        L = int(L) if L is not None else self.params.L
        queries = np.ascontiguousarray(queries, dtype=np.float32)
        Q = queries.shape[0]
        cap = self.vectors.shape[0]
        unique_masks = np.asarray(unique_masks, dtype=bool)
        if unique_masks.ndim == 1:
            unique_masks = unique_masks[None, :]
        mask_pad = np.zeros((unique_masks.shape[0], cap), dtype=bool)
        width = min(unique_masks.shape[1], cap)
        mask_pad[:, :width] = unique_masks[:, :width]
        idx_np = (
            np.zeros(Q, np.int32)
            if mask_idx is None
            else np.asarray(mask_idx, np.int32)
        )
        masks_j = jnp.asarray(mask_pad)
        out_d = np.full((Q, k), np.inf, np.float32)
        out_i = np.full((Q, k), -1, np.int64)
        max_iters = int(1.3 * L) + 8
        if use_pq:
            if self.pq is None or self.pq_codes is None:
                raise ValueError("graph has no PQ data; call attach_pq()")
            codes_j = jnp.asarray(self.pq_codes.astype(np.int32))
        else:
            vecs_j = jnp.asarray(self.vectors)
        adj_j = jnp.asarray(self.adjacency)
        for s in range(0, Q, batch):
            q = queries[s : s + batch]
            pad = batch - q.shape[0]
            qb = np.pad(q, ((0, pad), (0, 0))) if pad else q
            ib = idx_np[s : s + batch]
            ib = np.pad(ib, (0, pad)) if pad else ib
            if use_pq:
                luts = build_luts(self.pq, qb)
                res_i, _res_d, vis_i = _masked_beam_search(
                    codes_j,
                    adj_j,
                    jnp.int32(self.n),
                    jnp.int32(self.medoid),
                    luts,
                    masks_j,
                    jnp.asarray(ib),
                    L,
                    k,
                    max_iters,
                    self.params.metric,
                    True,
                )
                # full-precision rerank over admitted pool ∪ admissible
                # visited nodes (their vectors are already paged in during
                # traversal, same as search_pq's rerank): inadmissible rows
                # fold to pid=-1 and the gather-rerank kernel scores the
                # rest on-device
                from repro.kernels import device_cache, ops

                cand = np.concatenate([np.asarray(res_i), np.asarray(vis_i)], axis=1)
                sort_idx = np.argsort(cand, axis=1, kind="stable")
                s_ids = np.take_along_axis(cand, sort_idx, axis=1)
                safe = np.clip(s_ids, 0, cap - 1)
                adm = mask_pad[ib[:, None], safe] & (s_ids < self.n)
                dup = np.concatenate(
                    [
                        np.zeros((cand.shape[0], 1), bool),
                        s_ids[:, 1:] == s_ids[:, :-1],
                    ],
                    axis=1,
                )
                adm &= ~dup
                pids = np.where(adm, s_ids, -1).astype(np.int32)
                rd, ri = ops.gather_rerank(
                    jnp.asarray(qb),
                    device_cache.device_vectors(self),
                    jnp.asarray(pids),
                    k,
                    metric=self.params.metric,
                    backend="auto",
                )
                dists_np = np.asarray(rd)
                ids_np = np.asarray(ri, np.int64)
            else:
                res_i, res_d, _vis = _masked_beam_search(
                    vecs_j,
                    adj_j,
                    jnp.int32(self.n),
                    jnp.int32(self.medoid),
                    jnp.asarray(qb),
                    masks_j,
                    jnp.asarray(ib),
                    L,
                    k,
                    max_iters,
                    self.params.metric,
                    False,
                )
                dists_np = np.asarray(res_d)
                ids_np = np.asarray(res_i).astype(np.int64)
            ids_np = np.where(np.isfinite(dists_np), ids_np, -1)
            out_d[s : s + q.shape[0]] = dists_np[: q.shape[0]]
            out_i[s : s + q.shape[0]] = ids_np[: q.shape[0]]
        return out_d, out_i

    # -- mutation -----------------------------------------------------------------
    def _ensure_capacity(self, extra: int) -> None:
        need = self.n + extra
        cap = self.vectors.shape[0]
        if need <= cap:
            return
        new_cap = _round_capacity(need)
        self.vectors = np.concatenate(
            [self.vectors, np.zeros((new_cap - cap, self.dim), np.float32)]
        )
        self.adjacency = np.concatenate(
            [self.adjacency, np.full((new_cap - cap, self.params.R), -1, np.int32)]
        )
        self.tombstones = np.concatenate([self.tombstones, np.zeros(new_cap - cap, bool)])
        if self.pq_codes is not None:
            self.pq_codes = np.concatenate(
                [self.pq_codes, np.zeros((new_cap - cap, self.pq.m), np.uint8)]
            )

    def insert_batch(self, new_vectors: np.ndarray, batch: int = 64) -> np.ndarray:
        """Greedy insert (paper §7.2): beam search from medoid → robust prune
        → bidirectional edges → re-prune over-degree neighbors.
        Returns the assigned ids."""
        new_vectors = np.ascontiguousarray(new_vectors, dtype=np.float32)
        count = new_vectors.shape[0]
        self._ensure_capacity(count)
        ids = np.arange(self.n, self.n + count, dtype=np.int64)
        self.vectors[self.n : self.n + count] = new_vectors
        if self.pq is not None:
            self.pq_codes[self.n : self.n + count] = encode(self.pq, new_vectors)
        # keep n at pre-insert value during search so new points are invisible
        p = self.params
        max_iters = int(1.3 * p.L) + 8
        for s in range(0, count, batch):
            stop = min(s + batch, count)
            q = new_vectors[s:stop]
            pad = batch - q.shape[0]
            qb = np.pad(q, ((0, pad), (0, 0))) if pad else q
            pool_ids, pool_dists, vis_ids, vis_dists = _beam_search(
                jnp.asarray(self.vectors),
                jnp.asarray(self.adjacency),
                jnp.int32(self.n),
                jnp.int32(self.medoid),
                jnp.asarray(qb),
                p.L,
                max_iters,
                p.metric,
                False,
            )
            cand = jnp.concatenate([pool_ids, vis_ids], axis=1)
            nbrs = _robust_prune(
                jnp.asarray(self.vectors),
                jnp.asarray(qb),
                cand,
                jnp.int32(self.n),
                p.R,
                p.alpha,
                p.metric,
            )
            nbrs_np = np.asarray(nbrs)[: stop - s]
            batch_ids = ids[s:stop]
            self.adjacency[batch_ids] = nbrs_np
            self._add_reverse_edges(batch_ids, nbrs_np)
        self.n += count
        return ids

    def _add_reverse_edges(self, src_ids: np.ndarray, nbrs: np.ndarray) -> None:
        """Host-side scatter of reverse edges with robust-prune on overflow."""
        overflow: dict[int, list[int]] = {}
        for sid, row in zip(src_ids, nbrs):
            for nbr in row:
                if nbr < 0:
                    continue
                adj = self.adjacency[nbr]
                slot = np.flatnonzero(adj < 0)
                if sid in adj:
                    continue
                if len(slot):
                    adj[slot[0]] = sid
                else:
                    overflow.setdefault(int(nbr), []).append(int(sid))
        if overflow:
            self._reprune_nodes(overflow)

    def _reprune_nodes(self, overflow: dict) -> None:
        """Batch robust-prune nodes whose degree exceeded R.

        Shapes are bucketed (C to a multiple of 32, node count to the next
        power of two) so `_robust_prune` compiles only a handful of times
        over an entire build instead of once per batch.
        """
        p = self.params
        nodes = np.array(sorted(overflow.keys()), dtype=np.int64)
        max_extra = max(len(v) for v in overflow.values())
        C = p.R + max(32, 32 * int(np.ceil(max_extra / 32)))
        cap = self.vectors.shape[0]
        n_pad = 1 << int(np.ceil(np.log2(max(len(nodes), 1))))
        cand = np.full((n_pad, C), cap, dtype=np.int32)
        max_id = int(nodes.max())
        for i, node in enumerate(nodes):
            cur = self.adjacency[node]
            cur = cur[cur >= 0]
            extras = np.array(overflow[int(node)], dtype=np.int32)
            allc = np.concatenate([cur.astype(np.int32), extras])[:C]
            cand[i, : len(allc)] = allc
            if len(allc):
                max_id = max(max_id, int(allc.max()))
        p_vecs = np.zeros((n_pad, self.dim), np.float32)
        p_vecs[: len(nodes)] = self.vectors[nodes]
        # validity bound must cover mid-insert ids (>= self.n): their vectors
        # are already written, and excluding them silently drops every
        # reverse edge into a dense region (zero-reachability inserts)
        pruned = _robust_prune(
            jnp.asarray(self.vectors),
            jnp.asarray(p_vecs),
            jnp.asarray(cand),
            jnp.int32(max(self.n, max_id + 1)),
            p.R,
            p.alpha,
            p.metric,
        )
        self.adjacency[nodes] = np.asarray(pruned)[: len(nodes)]

    def tombstone(self, ids: np.ndarray) -> None:
        self.tombstones[np.asarray(ids, dtype=np.int64)] = True

    def attach_pq(self, pq: PQCodebook, codes: Optional[np.ndarray] = None) -> None:
        self.pq = pq
        if codes is None:
            codes = encode(pq, self.vectors[: self.n])
        full = np.zeros((self.vectors.shape[0], pq.m), np.uint8)
        full[: self.n] = codes[: self.n]
        self.pq_codes = full


# ---------------------------------------------------------------------------
# build
# ---------------------------------------------------------------------------


def _medoid(vectors: np.ndarray) -> int:
    mean = vectors.mean(axis=0, keepdims=True)
    d = np.sum((vectors - mean) ** 2, axis=1)
    return int(np.argmin(d))


def build_vamana(
    vectors: np.ndarray,
    params: VamanaParams = VamanaParams(),
    *,
    seed: int = 0,
    passes: int = 2,
    batch: int = 64,
    with_pq: bool = False,
    pq_m: Optional[int] = None,
    pq_nbits: int = 8,
) -> VamanaGraph:
    """Batch-parallel Vamana build.

    1. random R-regular init;
    2. ``passes`` refinement sweeps (first at α=1.0, last at α=params.alpha,
       per the DiskANN two-pass schedule): for every point, beam-search the
       current graph, robust-prune the visited set into its new neighbor
       list, then scatter reverse edges.
    """
    vectors = np.ascontiguousarray(vectors, dtype=np.float32)
    n, d = vectors.shape
    if n == 0:
        raise ValueError("empty build")
    rng = np.random.default_rng(seed)
    cap = _round_capacity(n)
    padded = np.zeros((cap, d), np.float32)
    padded[:n] = vectors
    adjacency = np.full((cap, params.R), -1, np.int32)
    if n > 1:
        for i in range(n):  # random init, self-loop free
            deg = min(params.R, n - 1)
            choices = rng.choice(n - 1, size=deg, replace=False)
            choices = choices + (choices >= i)
            adjacency[i, :deg] = choices
    graph = VamanaGraph(
        vectors=padded,
        adjacency=adjacency,
        n=n,
        medoid=_medoid(vectors),
        params=params,
    )
    max_iters = int(1.3 * params.L) + 8
    order = rng.permutation(n)
    for p_idx in range(passes):
        alpha = 1.0 if p_idx < passes - 1 else params.alpha
        for s in range(0, n, batch):
            sel = order[s : s + batch]
            q = vectors[sel]
            pad = batch - q.shape[0]
            qb = np.pad(q, ((0, pad), (0, 0))) if pad else q
            pool_ids, _pd, vis_ids, _vd = _beam_search(
                jnp.asarray(graph.vectors),
                jnp.asarray(graph.adjacency),
                jnp.int32(n),
                jnp.int32(graph.medoid),
                jnp.asarray(qb),
                params.L,
                max_iters,
                params.metric,
                False,
            )
            cand = np.concatenate([np.asarray(pool_ids), np.asarray(vis_ids)], axis=1)
            # a point must not select itself
            cand = np.where(cand == np.pad(sel, (0, pad))[:, None], cap, cand)
            nbrs = _robust_prune(
                jnp.asarray(graph.vectors),
                jnp.asarray(qb),
                jnp.asarray(cand),
                jnp.int32(n),
                params.R,
                alpha,
                params.metric,
            )
            nbrs_np = np.asarray(nbrs)[: len(sel)]
            graph.adjacency[sel] = nbrs_np
            graph._add_reverse_edges(sel, nbrs_np)
    if with_pq:
        m = pq_m if pq_m is not None else max(1, d // 16)
        from repro.core.pq import train_pq

        pq = train_pq(vectors, m=m, nbits=pq_nbits)
        graph.attach_pq(pq)
    return graph


def brute_force_topk(
    vectors: np.ndarray, queries: np.ndarray, k: int, metric: str = "l2"
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact ground truth for recall measurements."""
    from repro.kernels import ops

    d, i = ops.exact_topk(
        jnp.asarray(queries, jnp.float32), jnp.asarray(vectors, jnp.float32), k, metric=metric, backend="ref"
    )
    return np.asarray(d), np.asarray(i)


def recall_at_k(result_ids: np.ndarray, truth_ids: np.ndarray) -> float:
    hits = 0
    for r, t in zip(result_ids, truth_ids):
        hits += len(set(int(x) for x in r) & set(int(x) for x in t))
    return hits / truth_ids.size
