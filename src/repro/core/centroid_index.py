"""File-level centroid index (paper §3.3, §4.1) — the coordinator-tier index.

One entry per data file: the centroid of the file's vectors plus
``max_distance`` (the largest distance from the centroid to any vector in
the file).  Probing is ~10⁴ distance computations — sub-millisecond — so it
runs on the coordinator and prunes the file list before dispatch.

Pruning rules:
- **top-k queries**: keep the ``n_probe`` files with nearest centroids
  (recall/latency dial; paper Table 2 uses ~4 % of files).
- **threshold queries**: *exact* pruning — a file whose
  ``centroid_distance − max_distance > threshold`` cannot contain a match
  (triangle inequality; paper §4.1), so eliminating it is safe.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.blobs import decode_centroid_blob, encode_centroid_blob
from repro.lakehouse.table import LakehouseTable


@dataclass
class CentroidIndex:
    centroids: np.ndarray  # (F, D) f32
    max_distances: np.ndarray  # (F,) f32 — L2 (not squared) radius
    file_paths: List[str]
    metric: str = "l2"

    @property
    def num_files(self) -> int:
        return len(self.file_paths)

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]

    # -- probes ---------------------------------------------------------------
    def _centroid_dists(self, query: np.ndarray) -> np.ndarray:
        return self._centroid_dists_batch(query[None, :])[0]

    def _centroid_dists_batch(self, queries: np.ndarray) -> np.ndarray:
        """(B, D) → (B, F) centroid distances, one vectorized pass."""
        q = np.asarray(queries, np.float32)
        if self.metric == "ip":
            return -(q @ self.centroids.T)
        diff = self.centroids[None, :, :] - q[:, None, :]  # (B, F, D)
        return np.sqrt(np.maximum(np.einsum("bfd,bfd->bf", diff, diff), 0.0))

    def probe_topk(self, query: np.ndarray, n_probe: int) -> List[str]:
        """The ``n_probe`` most promising files for a top-K query."""
        return self.probe_topk_batch(np.asarray(query, np.float32)[None, :], n_probe)[0]

    def probe_topk_batch(self, queries: np.ndarray, n_probe: int) -> List[List[str]]:
        """Batched routing: per-query ``n_probe`` file lists from a single
        (B, F) distance computation instead of B sequential passes."""
        d = self._centroid_dists_batch(queries)
        keep = min(n_probe, self.num_files)
        order = np.argsort(d, axis=1)[:, :keep]
        return [[self.file_paths[i] for i in row] for row in order]

    def probe_threshold(self, query: np.ndarray, threshold: float) -> List[str]:
        """Exact pruning for ``WHERE dist < threshold`` queries (L2 only)."""
        if self.metric != "l2":
            raise ValueError("threshold pruning requires a true metric (l2)")
        d = self._centroid_dists(np.asarray(query, np.float32))
        keep = d - self.max_distances <= threshold
        return [self.file_paths[i] for i in np.flatnonzero(keep)]

    # -- blob codec ---------------------------------------------------------------
    def to_blob(self) -> bytes:
        return encode_centroid_blob(
            self.centroids,
            np.arange(self.num_files, dtype=np.uint32),
            self.max_distances,
            self.file_paths,
            self.metric,
        )

    @staticmethod
    def from_blob(data: bytes) -> "CentroidIndex":
        centroids, file_indices, max_distances, file_paths, metric = decode_centroid_blob(data)
        order = np.argsort(file_indices)
        return CentroidIndex(
            centroids=centroids[order],
            max_distances=max_distances[order],
            file_paths=[file_paths[int(file_indices[i])] for i in order],
            metric=metric,
        )

    def size_bytes(self) -> int:
        """Uncompressed entry-section size — validates the paper's 30.8 MB
        figure for 10⁴ files × 768 d (§4.1)."""
        return self.num_files * (self.dim * 4 + 8)


def build_centroid_index(
    table: LakehouseTable,
    snapshot_id: Optional[int] = None,
    metric: str = "l2",
) -> CentroidIndex:
    """Scan each data file's vector column and compute (centroid, radius)."""
    files = table.current_files(snapshot_id)
    cents: List[np.ndarray] = []
    radii: List[float] = []
    paths: List[str] = []
    for f in files:
        reader = table.reader(f.path)
        vecs = reader.read_column("vec")
        if vecs.shape[0] == 0:
            continue
        c = vecs.mean(axis=0)
        diff = vecs - c[None, :]
        radius = float(np.sqrt(np.max(np.einsum("nd,nd->n", diff, diff))))
        cents.append(c.astype(np.float32))
        radii.append(radius)
        paths.append(f.path)
    if not cents:
        raise ValueError("no data files with vectors")
    return CentroidIndex(
        centroids=np.stack(cents),
        max_distances=np.asarray(radii, np.float32),
        file_paths=paths,
        metric=metric,
    )
