"""Byte-level blob codecs for the three Puffin blob types (paper §4).

- ``flockdb-ann-centroid-v1`` (:func:`encode_centroid_blob`) — §4.1: 32-byte
  header (magic ``ANNI``), fixed-size entries ``(centroid f32[D], file_idx
  u32, max_distance f32)``, length-prefixed UTF-8 file-paths table.
- ``flockdb-ann-index-v1`` (:func:`encode_shard_blob`) — §4.3: header (magic
  ``DANN``, version, dims, count, R, L, medoid, metric, PQ params), PQ
  codebook, PQ codes, adjacency offset table (N+1 × u64), zstd-compressed
  varint adjacency (per-node degree + neighbor ids), optional full f32
  vectors (the paper's retention policy: omit when the engine can re-fetch
  from Parquet during rerank), delta-encoded vector-ID→location map,
  tombstone bitmap.
- ``flockdb-ann-routing-v1`` (:func:`encode_routing_blob`) — JSON metadata
  (shard table, tombstone ratios, base snapshot id, params) + binary
  partition-centroid codebook.
- ``repro.attr-zonemap-v1`` (:func:`encode_zonemap_blob`) — filtered
  search: per-(file, row-group) attribute zones (min/max for numeric
  columns, value→count tags for dictionary columns) plus per-shard
  row-group membership, so the coordinator can prune shards and row
  groups against WHERE predicates before dispatch.
- ``repro.fresh-tail-v1`` (:func:`encode_fresh_tail_blob`) — freshness:
  the appended-but-unindexed row groups committed since the last indexed
  snapshot.  Append commits maintain it; probes serve the listed row
  groups through exact-scan plan ops so writes are searchable without a
  rebuild; a refresh/compaction resets it.

Deviation from the paper, recorded per DESIGN.md: the shard blob carries the
PQ **codes** section explicitly.  The paper lists only the codebook, but the
probe path it describes ("PQ-approximate distances for candidate scoring")
requires per-vector codes; DiskANN stores them in a sidecar file, we inline
them as a section.
"""

from __future__ import annotations

import io
import json
import struct
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

try:
    import zstandard as _zstd

    def _c(b: bytes) -> bytes:
        return _zstd.ZstdCompressor(level=3).compress(b)

    def _d(b: bytes) -> bytes:
        return _zstd.ZstdDecompressor().decompress(b)

except Exception:  # pragma: no cover
    import zlib

    def _c(b: bytes) -> bytes:
        return zlib.compress(b, 6)

    def _d(b: bytes) -> bytes:
        return zlib.decompress(b)


CENTROID_BLOB_TYPE = "flockdb-ann-centroid-v1"
SHARD_BLOB_TYPE = "flockdb-ann-index-v1"
ROUTING_BLOB_TYPE = "flockdb-ann-routing-v1"
ATTR_ZONEMAP_BLOB_TYPE = "repro.attr-zonemap-v1"
FRESH_TAIL_BLOB_TYPE = "repro.fresh-tail-v1"

_METRIC_CODE = {"l2": 0, "ip": 1}
_METRIC_NAME = {v: k for k, v in _METRIC_CODE.items()}


# ---------------------------------------------------------------------------
# varints
# ---------------------------------------------------------------------------


def _write_varint(buf: io.BytesIO, value: int) -> None:
    while True:
        b = value & 0x7F
        value >>= 7
        if value:
            buf.write(bytes([b | 0x80]))
        else:
            buf.write(bytes([b]))
            return


def _read_varint(data: bytes, pos: int) -> Tuple[int, int]:
    out = 0
    shift = 0
    while True:
        b = data[pos]
        pos += 1
        out |= (b & 0x7F) << shift
        if not (b & 0x80):
            return out, pos
        shift += 7


# ---------------------------------------------------------------------------
# centroid blob (ANNI, §4.1)
# ---------------------------------------------------------------------------

_ANNI_MAGIC = b"ANNI"
_ANNI_HEADER = struct.Struct("<4sBBHIIIQI")  # magic, ver, metric, entry_size,
# dims, entry_count, file_count, paths_offset, reserved  -> 32 bytes


@dataclass
class CentroidEntry:
    centroid: np.ndarray  # (D,) f32
    file_index: int
    max_distance: float


def encode_centroid_blob(
    centroids: np.ndarray,  # (N, D) f32
    file_indices: np.ndarray,  # (N,) u32 (index into file_paths)
    max_distances: np.ndarray,  # (N,) f32
    file_paths: List[str],
    metric: str = "l2",
) -> bytes:
    centroids = np.ascontiguousarray(centroids, dtype=np.float32)
    n, d = centroids.shape
    entry_size = d * 4 + 4 + 4
    entries = io.BytesIO()
    fi = np.asarray(file_indices, dtype=np.uint32)
    md = np.asarray(max_distances, dtype=np.float32)
    for i in range(n):
        entries.write(centroids[i].tobytes())
        entries.write(struct.pack("<If", int(fi[i]), float(md[i])))
    entry_bytes = entries.getvalue()
    paths = io.BytesIO()
    paths.write(struct.pack("<I", len(file_paths)))
    for p in file_paths:
        raw = p.encode("utf-8")
        paths.write(struct.pack("<H", len(raw)))
        paths.write(raw)
    paths_offset = _ANNI_HEADER.size + len(entry_bytes)
    header = _ANNI_HEADER.pack(
        _ANNI_MAGIC, 1, _METRIC_CODE[metric], entry_size, d, n, len(file_paths), paths_offset, 0
    )
    return header + entry_bytes + paths.getvalue()


def decode_centroid_blob(data: bytes):
    magic, ver, metric_code, entry_size, d, n, n_files, paths_offset, _r = _ANNI_HEADER.unpack(
        data[: _ANNI_HEADER.size]
    )
    if magic != _ANNI_MAGIC:
        raise ValueError("bad ANNI magic")
    centroids = np.empty((n, d), np.float32)
    file_indices = np.empty(n, np.uint32)
    max_distances = np.empty(n, np.float32)
    pos = _ANNI_HEADER.size
    for i in range(n):
        centroids[i] = np.frombuffer(data, np.float32, d, pos)
        pos += d * 4
        file_indices[i], max_distances[i] = struct.unpack_from("<If", data, pos)
        pos += 8
    pos = paths_offset
    (count,) = struct.unpack_from("<I", data, pos)
    pos += 4
    file_paths: List[str] = []
    for _ in range(count):
        (ln,) = struct.unpack_from("<H", data, pos)
        pos += 2
        file_paths.append(data[pos : pos + ln].decode("utf-8"))
        pos += ln
    return centroids, file_indices, max_distances, file_paths, _METRIC_NAME[metric_code]


# ---------------------------------------------------------------------------
# shard blob (DANN, §4.3)
# ---------------------------------------------------------------------------

_DANN_MAGIC = b"DANN"
# magic, version, dims, count, R, L, medoid, metric, has_vectors, pq_m,
# pq_nbits, alpha, 7 section offsets (codebook, codes, adj_offsets, adjacency,
# vectors, locmap, tombstones)
_DANN_HEADER = struct.Struct("<4sIIQIIQBBHHf7Q")


@dataclass
class ShardLocationMap:
    """vector id -> (file_path, row_group_id, row_offset); §4.3."""

    file_paths: List[str]
    file_idx: np.ndarray  # (N,) u32
    row_group: np.ndarray  # (N,) u32
    row_offset: np.ndarray  # (N,) u32

    def lookup(self, vec_id: int) -> Tuple[str, int, int]:
        return (
            self.file_paths[int(self.file_idx[vec_id])],
            int(self.row_group[vec_id]),
            int(self.row_offset[vec_id]),
        )


def _encode_locmap(loc: ShardLocationMap) -> bytes:
    buf = io.BytesIO()
    buf.write(struct.pack("<I", len(loc.file_paths)))
    for p in loc.file_paths:
        raw = p.encode("utf-8")
        buf.write(struct.pack("<H", len(raw)))
        buf.write(raw)
    n = len(loc.file_idx)
    buf.write(struct.pack("<Q", n))
    # delta-encode each stream (ids are the sorted order already — §4.3)
    for arr in (loc.file_idx, loc.row_group, loc.row_offset):
        a = np.asarray(arr, dtype=np.int64)
        deltas = np.diff(a, prepend=0)
        # zig-zag so negatives stay compact
        zz = ((deltas << 1) ^ (deltas >> 63)).astype(np.uint64)
        sub = io.BytesIO()
        for v in zz.tolist():
            _write_varint(sub, int(v))
        raw = sub.getvalue()
        buf.write(struct.pack("<Q", len(raw)))
        buf.write(raw)
    return _c(buf.getvalue())


def _decode_locmap(data: bytes) -> ShardLocationMap:
    data = _d(data)
    pos = 0
    (n_files,) = struct.unpack_from("<I", data, pos)
    pos += 4
    file_paths = []
    for _ in range(n_files):
        (ln,) = struct.unpack_from("<H", data, pos)
        pos += 2
        file_paths.append(data[pos : pos + ln].decode("utf-8"))
        pos += ln
    (n,) = struct.unpack_from("<Q", data, pos)
    pos += 8
    streams = []
    for _ in range(3):
        (ln,) = struct.unpack_from("<Q", data, pos)
        pos += 8
        raw = data[pos : pos + ln]
        pos += ln
        vals = np.empty(n, np.int64)
        p = 0
        for i in range(n):
            v, p = _read_varint(raw, p)
            vals[i] = (v >> 1) ^ -(v & 1)  # un-zigzag
        streams.append(np.cumsum(vals).astype(np.uint32) if n else vals.astype(np.uint32))
    return ShardLocationMap(file_paths, streams[0], streams[1], streams[2])


def encode_shard_blob(
    graph,  # VamanaGraph
    locmap: ShardLocationMap,
    *,
    include_vectors: bool = True,
) -> bytes:
    """Serialize a Vamana shard to the DANN layout."""
    from repro.core.vamana import VamanaGraph  # local import to avoid cycle

    assert isinstance(graph, VamanaGraph)
    n = graph.n
    d = graph.dim
    p = graph.params
    pq = graph.pq
    codebook_bytes = pq.tobytes() if pq is not None else b""
    pq_m = pq.m if pq is not None else 0
    pq_nbits = pq.nbits if pq is not None else 0
    codes_bytes = (
        _c(np.ascontiguousarray(graph.pq_codes[:n]).tobytes()) if pq is not None else b""
    )
    # adjacency: varint per §4.3, zstd over the whole section
    adj = graph.adjacency[:n]
    offsets = np.zeros(n + 1, np.uint64)
    body = io.BytesIO()
    for i in range(n):
        row = adj[i]
        row = row[row >= 0]
        _write_varint(body, len(row))
        for v in row.tolist():
            _write_varint(body, int(v))
        offsets[i + 1] = body.tell()
    adjacency_bytes = _c(body.getvalue())
    offsets_bytes = offsets.tobytes()
    vectors_bytes = (
        np.ascontiguousarray(graph.vectors[:n], dtype=np.float32).tobytes()
        if include_vectors
        else b""
    )
    locmap_bytes = _encode_locmap(locmap)
    tombstone_bytes = np.packbits(graph.tombstones[:n]).tobytes()

    header_size = _DANN_HEADER.size
    off = header_size
    section_offsets = []
    for blob in (codebook_bytes, codes_bytes, offsets_bytes, adjacency_bytes, vectors_bytes, locmap_bytes, tombstone_bytes):
        section_offsets.append(off)
        off += len(blob)
    header = _DANN_HEADER.pack(
        _DANN_MAGIC,
        1,
        d,
        n,
        p.R,
        p.L,
        graph.medoid,
        _METRIC_CODE[p.metric],
        1 if include_vectors else 0,
        pq_m,
        pq_nbits,
        p.alpha,
        *section_offsets,
    )
    return b"".join(
        [header, codebook_bytes, codes_bytes, offsets_bytes, adjacency_bytes, vectors_bytes, locmap_bytes, tombstone_bytes]
    )


def decode_shard_blob(
    data: bytes,
    *,
    vectors_override: Optional[np.ndarray] = None,
    lazy_vectors: bool = False,
):
    """Decode a DANN blob back into a (VamanaGraph, ShardLocationMap).

    ``vectors_override`` supplies full vectors when the blob was written with
    ``include_vectors=False`` (the paper's re-fetch-from-Parquet policy);
    ``lazy_vectors=True`` instead returns the graph with zeroed vectors so
    the caller can fetch them through the location map (the executor's lean
    path).
    """
    from repro.core.pq import PQCodebook
    from repro.core.vamana import VamanaGraph, VamanaParams, _round_capacity

    (
        magic,
        version,
        d,
        n,
        R,
        L,
        medoid,
        metric_code,
        has_vectors,
        pq_m,
        pq_nbits,
        alpha,
        off_codebook,
        off_codes,
        off_offsets,
        off_adjacency,
        off_vectors,
        off_locmap,
        off_tombstones,
    ) = _DANN_HEADER.unpack(data[: _DANN_HEADER.size])
    if magic != _DANN_MAGIC:
        raise ValueError("bad DANN magic")
    metric = _METRIC_NAME[metric_code]
    params = VamanaParams(R=R, L=L, alpha=alpha, metric=metric)
    pq = None
    codes = None
    if pq_m:
        K = 1 << pq_nbits
        dsub = d // pq_m
        pq = PQCodebook.frombytes(data[off_codebook:off_codes], pq_m, K, dsub, metric)
        codes = np.frombuffer(_d(data[off_codes:off_offsets]), np.uint8).reshape(n, pq_m)
    adj_raw = _d(data[off_adjacency:off_vectors])
    cap = _round_capacity(n)
    adjacency = np.full((cap, R), -1, np.int32)
    pos = 0
    for i in range(n):
        deg, pos = _read_varint(adj_raw, pos)
        for j in range(deg):
            v, pos = _read_varint(adj_raw, pos)
            adjacency[i, j] = v
    if has_vectors:
        vectors = np.frombuffer(data[off_vectors:off_locmap], np.float32).reshape(n, d)
    elif vectors_override is not None:
        vectors = np.ascontiguousarray(vectors_override, dtype=np.float32)
        if vectors.shape != (n, d):
            raise ValueError(f"override shape {vectors.shape} != ({n},{d})")
    elif lazy_vectors:
        vectors = np.zeros((n, d), np.float32)
    else:
        raise ValueError("blob has no vectors and no override provided")
    padded = np.zeros((cap, d), np.float32)
    padded[:n] = vectors
    tombstones = np.unpackbits(
        np.frombuffer(data[off_tombstones:], np.uint8), count=n
    ).astype(bool)
    ts = np.zeros(cap, bool)
    ts[:n] = tombstones
    graph = VamanaGraph(
        vectors=padded,
        adjacency=adjacency,
        n=n,
        medoid=medoid,
        params=params,
        tombstones=ts,
    )
    if pq is not None:
        graph.attach_pq(pq, codes)
    locmap = _decode_locmap(data[off_locmap:off_tombstones])
    return graph, locmap


# ---------------------------------------------------------------------------
# attribute zone-map blob (repro.attr-zonemap-v1)
# ---------------------------------------------------------------------------


@dataclass
class AttrZoneMap:
    """Per-(file, row_group) attribute zones + per-shard row-group membership.

    ``zones[file][rg][column]`` is a :class:`repro.runtime.predicates.ZoneStats`
    — min/max for numeric columns, value→count tags for dictionary columns.
    ``shard_membership[shard_id]`` lists the (file, row_group) pairs whose
    rows the shard indexed, so the coordinator can skip a whole shard when no
    member zone can satisfy a predicate; ``None`` (e.g. after a refresh that
    didn't recompute membership) disables pruning for that shard but keeps
    the row-group statistics usable for planning."""

    columns: Dict[str, str]  # column name -> "int" | "dict"
    zones: Dict[str, List[Dict[str, "ZoneStats"]]]
    shard_membership: Optional[Dict[int, List[Tuple[str, int]]]] = None
    # shard-level merged histograms (computed on demand from the decoded
    # file-level histograms, memoized per shard)
    _shard_hist_cache: Dict[int, Dict[str, object]] = field(
        default_factory=dict, repr=False, compare=False
    )

    def _shard_hists(self, shard_id: int) -> Dict[str, object]:
        """Per-column histograms merged across the shard's member FILES.

        A shard typically indexes rows from several files; estimating a
        Range predicate's passing fraction against each row group's
        file-level histogram lets one file's distribution stand in for the
        shard's.  Merging the distinct member files' histograms (re-binned
        over the union range) gives ``plan_filtered`` shard-level evidence
        instead.  Shards spanning a single file keep the file histogram
        bit-for-bit.  Cached per shard; the merged histogram is in-memory
        only, never serialized."""
        cached = self._shard_hist_cache.get(shard_id)
        if cached is not None:
            return cached
        from repro.runtime.predicates import ColumnHistogram

        per_col: Dict[str, List[object]] = {}
        seen: Dict[str, set] = {}
        files = sorted({fp for fp, _ in self.shard_membership[shard_id]})
        for fp in files:
            for rg in self.zones.get(fp, []):
                for col, z in rg.items():
                    if z.hist is not None and col not in seen.setdefault(fp, set()):
                        seen[fp].add(col)
                        per_col.setdefault(col, []).append(z.hist)
        merged = {
            col: ColumnHistogram.merge(hists)
            for col, hists in per_col.items()
            if len(hists) > 1
        }
        merged = {col: h for col, h in merged.items() if h is not None}
        self._shard_hist_cache[shard_id] = merged
        return merged

    def shard_zones(self, shard_id: int) -> Optional[List[Dict[str, "ZoneStats"]]]:
        """The member zones of one shard (None = membership unknown), with
        each zone's histogram upgraded from file-level to the shard-level
        merge (see :meth:`_shard_hists`) so selectivity estimates reflect
        every file the shard indexed."""
        if self.shard_membership is None or shard_id not in self.shard_membership:
            return None
        from dataclasses import replace as _replace

        shard_hists = self._shard_hists(shard_id)
        out = []
        for fp, rg in self.shard_membership[shard_id]:
            per_file = self.zones.get(fp)
            if per_file is None or rg >= len(per_file):
                return None  # stale membership: never prune on partial info
            entry = per_file[rg]
            if shard_hists:
                entry = {
                    col: (
                        _replace(z, hist=shard_hists[col])
                        if z.hist is not None and col in shard_hists
                        else z
                    )
                    for col, z in entry.items()
                }
            out.append(entry)
        return out


def encode_zonemap_blob(zm: AttrZoneMap) -> bytes:
    # per-file equi-width int histograms are stored ONCE per (file, column)
    # — each row group's ZoneStats references the shared file-level
    # histogram, so serializing it inside every zone entry would only
    # duplicate bytes
    histograms: Dict[str, Dict[str, dict]] = {}
    for fp, per_file in zm.zones.items():
        for rg in per_file:
            for col, z in rg.items():
                if z.hist is not None and col not in histograms.get(fp, {}):
                    histograms.setdefault(fp, {})[col] = z.hist.to_json()
    meta = {
        "version": 1,
        "columns": dict(zm.columns),
        "zones": {
            fp: [{c: z.to_json() for c, z in rg.items()} for rg in per_file]
            for fp, per_file in zm.zones.items()
        },
        "histograms": histograms or None,
        "shard-membership": (
            {str(sid): [[fp, rg] for fp, rg in pairs] for sid, pairs in zm.shard_membership.items()}
            if zm.shard_membership is not None
            else None
        ),
    }
    return _c(json.dumps(meta, separators=(",", ":")).encode("utf-8"))


def decode_zonemap_blob(data: bytes) -> AttrZoneMap:
    from dataclasses import replace as _replace

    from repro.runtime.predicates import ColumnHistogram, ZoneStats

    meta = json.loads(_d(data).decode("utf-8"))
    membership = meta.get("shard-membership")
    histograms = {
        fp: {c: ColumnHistogram.from_json(h) for c, h in cols.items()}
        for fp, cols in (meta.get("histograms") or {}).items()
    }
    zones: Dict[str, List[Dict[str, ZoneStats]]] = {}
    for fp, per_file in meta["zones"].items():
        file_hists = histograms.get(fp, {})
        decoded = []
        for rg in per_file:
            entry = {c: ZoneStats.from_json(z) for c, z in rg.items()}
            for c, h in file_hists.items():
                if c in entry:
                    entry[c] = _replace(entry[c], hist=h)
            decoded.append(entry)
        zones[fp] = decoded
    return AttrZoneMap(
        columns=dict(meta["columns"]),
        zones=zones,
        shard_membership=(
            {int(sid): [(fp, int(rg)) for fp, rg in pairs] for sid, pairs in membership.items()}
            if membership is not None
            else None
        ),
    )


def build_zonemap(store, file_paths: List[str]) -> Optional[AttrZoneMap]:
    """Scan the attribute columns of ``file_paths`` into an AttrZoneMap.

    Returns None when the table carries no attribute columns (pure-vector
    tables get no zone-map blob at all)."""
    from dataclasses import replace as _replace

    from repro.lakehouse.vparquet import VParquetReader
    from repro.runtime.predicates import ColumnHistogram, ZoneStats

    columns: Dict[str, str] = {}
    zones: Dict[str, List[Dict[str, ZoneStats]]] = {}
    for fp in file_paths:
        reader = VParquetReader.from_store(store, fp)
        attr_specs = reader.attribute_specs()
        per_file: List[Dict[str, ZoneStats]] = []
        int_values: Dict[str, List[np.ndarray]] = {}
        for rg_id in range(reader.num_row_groups):
            rg_zones: Dict[str, ZoneStats] = {}
            for name, spec in attr_specs.items():
                arr = reader.read_column(name, [rg_id])
                if spec.dictionary is not None:
                    columns[name] = "dict"
                    codes, counts = np.unique(arr, return_counts=True)
                    rg_zones[name] = ZoneStats(
                        count=int(arr.shape[0]),
                        values={
                            spec.dictionary[int(c)]: int(n) for c, n in zip(codes, counts)
                        },
                    )
                else:
                    columns[name] = "int"
                    int_values.setdefault(name, []).append(arr)
                    rg_zones[name] = ZoneStats(
                        count=int(arr.shape[0]),
                        min=(arr.min().item() if arr.shape[0] else 0),
                        max=(arr.max().item() if arr.shape[0] else 0),
                    )
            per_file.append(rg_zones)
        # per-file equi-width histograms for int columns: shared by every
        # row group's ZoneStats — range-predicate selectivity estimation
        # (predicates.Range.estimate_fraction) reads them, and the planner
        # sizes PostfilterBeam pools from the result
        for name, parts in int_values.items():
            hist = ColumnHistogram.build(np.concatenate(parts))
            if hist is None:
                continue
            for rg_zones in per_file:
                if name in rg_zones:
                    rg_zones[name] = _replace(rg_zones[name], hist=hist)
        zones[fp] = per_file
    if not columns:
        return None
    return AttrZoneMap(columns=columns, zones=zones)


# ---------------------------------------------------------------------------
# fresh-tail blob (repro.fresh-tail-v1)
# ---------------------------------------------------------------------------


@dataclass
class TailEntry:
    """One appended-but-unindexed data file: its row groups and their sizes."""

    file_path: str
    row_groups: List[int]
    row_counts: List[int]

    @property
    def num_rows(self) -> int:
        return int(sum(self.row_counts))


@dataclass
class FreshTail:
    """The fresh-tail tier manifest: row groups appended since the last
    indexed snapshot.  ``base_snapshot_id`` is the snapshot the bound index
    actually covers; every entry lists one data file committed after it.
    Probes serve these row groups through exact-scan plan ops alongside the
    graph shards, so appends are searchable without a rebuild; a compaction
    (refresh_index) folds them into the shards and resets the tail."""

    base_snapshot_id: int
    entries: List[TailEntry]

    @property
    def total_rows(self) -> int:
        return int(sum(e.num_rows for e in self.entries))

    @property
    def total_row_groups(self) -> int:
        return int(sum(len(e.row_groups) for e in self.entries))

    def row_group_list(self) -> List[Tuple[str, int, int]]:
        """Flat (file_path, row_group, row_count) triples in tail order —
        the enumeration that defines each row group's synthetic plan-grid
        id (-1, -2, ... in this order)."""
        out: List[Tuple[str, int, int]] = []
        for e in self.entries:
            for rg, cnt in zip(e.row_groups, e.row_counts):
                out.append((e.file_path, int(rg), int(cnt)))
        return out


def encode_fresh_tail_blob(tail: FreshTail) -> bytes:
    meta = {
        "version": 1,
        "base-snapshot-id": tail.base_snapshot_id,
        "entries": [
            {
                "file": e.file_path,
                "row-groups": [int(g) for g in e.row_groups],
                "row-counts": [int(c) for c in e.row_counts],
            }
            for e in tail.entries
        ],
    }
    return _c(json.dumps(meta, separators=(",", ":")).encode("utf-8"))


def decode_fresh_tail_blob(data: bytes) -> FreshTail:
    meta = json.loads(_d(data).decode("utf-8"))
    return FreshTail(
        base_snapshot_id=int(meta["base-snapshot-id"]),
        entries=[
            TailEntry(
                file_path=e["file"],
                row_groups=[int(g) for g in e["row-groups"]],
                row_counts=[int(c) for c in e["row-counts"]],
            )
            for e in meta["entries"]
        ],
    )


# ---------------------------------------------------------------------------
# routing blob
# ---------------------------------------------------------------------------


@dataclass
class ShardInfo:
    shard_id: int
    blob_index: int  # index of this shard's blob within the Puffin file
    vector_count: int
    byte_size: int
    tombstone_ratio: float = 0.0
    executor_hint: str = ""


@dataclass
class RoutingTable:
    base_snapshot_id: int
    dims: int
    metric: str
    params: Dict[str, str]  # R, L, alpha, pq_m, pq_nbits...
    shards: List[ShardInfo]
    covered_files: List[str]
    partition_centroids: np.ndarray  # (P, D) f32 — Stage-0 codebook
    shard_of_partition: Optional[np.ndarray] = None  # (P,) u32

    @property
    def num_shards(self) -> int:
        return len(self.shards)


def encode_routing_blob(rt: RoutingTable) -> bytes:
    meta = {
        "base-snapshot-id": rt.base_snapshot_id,
        "dims": rt.dims,
        "metric": rt.metric,
        "params": rt.params,
        "covered-files": rt.covered_files,
        "shards": [
            {
                "shard-id": s.shard_id,
                "blob-index": s.blob_index,
                "vector-count": s.vector_count,
                "byte-size": s.byte_size,
                "tombstone-ratio": s.tombstone_ratio,
                "executor-hint": s.executor_hint,
            }
            for s in rt.shards
        ],
        "num-partitions": int(rt.partition_centroids.shape[0]),
        "shard-of-partition": (
            rt.shard_of_partition.tolist() if rt.shard_of_partition is not None else None
        ),
    }
    meta_raw = json.dumps(meta, separators=(",", ":")).encode("utf-8")
    cents = np.ascontiguousarray(rt.partition_centroids, dtype=np.float32).tobytes()
    return struct.pack("<I", len(meta_raw)) + meta_raw + cents


def decode_routing_blob(data: bytes) -> RoutingTable:
    (meta_len,) = struct.unpack_from("<I", data, 0)
    meta = json.loads(data[4 : 4 + meta_len].decode("utf-8"))
    p = meta["num-partitions"]
    d = meta["dims"]
    cents = np.frombuffer(data, np.float32, p * d, 4 + meta_len).reshape(p, d).copy()
    shards = [
        ShardInfo(
            shard_id=s["shard-id"],
            blob_index=s["blob-index"],
            vector_count=s["vector-count"],
            byte_size=s["byte-size"],
            tombstone_ratio=s.get("tombstone-ratio", 0.0),
            executor_hint=s.get("executor-hint", ""),
        )
        for s in meta["shards"]
    ]
    sop = meta.get("shard-of-partition")
    return RoutingTable(
        base_snapshot_id=meta["base-snapshot-id"],
        dims=d,
        metric=meta["metric"],
        params=dict(meta["params"]),
        shards=shards,
        covered_files=list(meta["covered-files"]),
        partition_centroids=cents,
        shard_of_partition=np.asarray(sop, np.uint32) if sop is not None else None,
    )
