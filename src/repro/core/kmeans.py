"""k-means (Lloyd) in JAX — Stage-0 centroid training and PQ codebooks.

The paper's coordinator trains ``k = num_executors × partitions_per_executor``
centroids over a ~1 % sample (§5 Stage 0), and PQ training runs k-means per
subquantizer (§4.3).  Assignment uses the ``kmeans_assign`` kernel; the
update step is a jit'd segment-sum.  Empty clusters are re-seeded from the
points currently farthest from their centroid (standard Lloyd repair).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def kmeans_plus_plus_init(
    points: np.ndarray, k: int, rng: np.random.Generator, sample_cap: int = 16384
) -> np.ndarray:
    """k-means++ seeding (host-side; runs once per training call)."""
    n = points.shape[0]
    if n > sample_cap:
        points = points[rng.choice(n, size=sample_cap, replace=False)]
        n = sample_cap
    centroids = np.empty((k, points.shape[1]), dtype=np.float32)
    centroids[0] = points[rng.integers(n)]
    d2 = np.full(n, np.inf, dtype=np.float64)
    for i in range(1, k):
        diff = points - centroids[i - 1]
        d2 = np.minimum(d2, np.einsum("nd,nd->n", diff, diff))
        total = d2.sum()
        if total <= 0:
            centroids[i:] = points[rng.integers(n, size=k - i)]
            break
        probs = d2 / total
        centroids[i] = points[rng.choice(n, p=probs)]
    return centroids


@functools.partial(jax.jit, static_argnames=("k",))
def _lloyd_step(
    points: jnp.ndarray, centroids: jnp.ndarray, k: int
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    assign, dist = ops.kmeans_assign(points, centroids, backend="ref")
    ones = jnp.ones((points.shape[0],), jnp.float32)
    counts = jax.ops.segment_sum(ones, assign, num_segments=k)
    sums = jax.ops.segment_sum(points, assign, num_segments=k)
    new_centroids = sums / jnp.maximum(counts, 1.0)[:, None]
    # keep old centroid where the cluster went empty (repaired on host)
    new_centroids = jnp.where((counts > 0)[:, None], new_centroids, centroids)
    return new_centroids, counts, jnp.sum(dist)


def train_kmeans(
    points: np.ndarray,
    k: int,
    *,
    iters: int = 20,
    seed: int = 0,
    repair_empty: bool = True,
) -> Tuple[np.ndarray, float]:
    """Returns (centroids (k, D) f32, final inertia)."""
    points = np.ascontiguousarray(points, dtype=np.float32)
    n = points.shape[0]
    if k > n:
        raise ValueError(f"k={k} > n={n}")
    rng = np.random.default_rng(seed)
    centroids = kmeans_plus_plus_init(points, k, rng)
    pts_j = jnp.asarray(points)
    inertia = float("inf")
    for _ in range(iters):
        cen_j, counts, inertia_j = _lloyd_step(pts_j, jnp.asarray(centroids), k)
        centroids = np.asarray(cen_j)
        counts = np.asarray(counts)
        inertia = float(inertia_j)
        if repair_empty and (counts == 0).any():
            # re-seed empty clusters at the points farthest from their centroid
            _, dist = ops.kmeans_assign(pts_j, jnp.asarray(centroids), backend="ref")
            far = np.argsort(-np.asarray(dist))
            empties = np.flatnonzero(counts == 0)
            centroids[empties] = points[far[: len(empties)]]
    return centroids, inertia


def assign(points: np.ndarray, centroids: np.ndarray) -> np.ndarray:
    """Host-facing nearest-centroid assignment (used for shard ownership)."""
    idx, _ = ops.kmeans_assign(jnp.asarray(points, dtype=jnp.float32), jnp.asarray(centroids, dtype=jnp.float32), backend="ref")
    return np.asarray(idx)
