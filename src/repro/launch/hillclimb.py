import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf hillclimbing on the three chosen cells (EXPERIMENTS.md §Perf).

Each VARIANT is a (cell, hypothesis, change) triple; running it lowers the
modified step, recomputes the roofline terms, and appends a JSONL row with
the before/after deltas.  Variants are cumulative within a cell where noted.

    PYTHONPATH=src python -m repro.launch.hillclimb            # all variants
    PYTHONPATH=src python -m repro.launch.hillclimb qwen.b16   # one
"""

import json
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.analysis.flops import count_jaxpr_flops
from repro.analysis.hlo import collective_bytes_from_hlo
from repro.analysis.roofline import compute_roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SERVE_RULES, build_cell
from repro.models.sharding import DEFAULT_RULES, with_rules

OUT = "results/hillclimb.jsonl"

# variant id -> dict(cell, hypothesis, knobs)
VARIANTS = {
    # ------------------------------------------------------------------
    # Cell A: qwen2.5-3b × train_4k (most collective-bound: t_coll≈t_comp)
    # ------------------------------------------------------------------
    "qwen.base": dict(
        arch="qwen2.5-3b", shape="train_4k",
        hypothesis="baseline (paper-faithful megatron TP16 × DP16, f32 params)",
        knobs={},
    ),
    "qwen.bf16params": dict(
        arch="qwen2.5-3b", shape="train_4k",
        hypothesis=(
            "grad/param collectives are f32 because params are f32 masters; "
            "storing bf16 params (f32 m/v in optimizer) halves every "
            "param-sized and grad-sized collective payload → predict "
            "t_collective ≈ 0.5× with unchanged t_compute"
        ),
        knobs=dict(train_param_dtype=jnp.bfloat16),
    ),
    "qwen.sparseattn": dict(
        arch="qwen2.5-3b", shape="train_4k",
        hypothesis=(
            "cumulative w/ bf16: dense flash pays 2× causal attention FLOPs; "
            "block-sparse schedule removes the upper triangle → predict "
            "t_compute down by ~attention share (~10-15%) and useful_ratio up"
        ),
        knobs=dict(train_param_dtype=jnp.bfloat16, cfg_overrides={"attn_impl": "sparse"}),
    ),
    "qwen.micro4": dict(
        arch="qwen2.5-3b", shape="train_4k",
        hypothesis=(
            "cumulative: fewer, larger microbatches (8→4) amortize per-pass "
            "param traffic (3 passes/micro) → predict t_memory down ~2×, "
            "collectives unchanged (activation-dominated)"
        ),
        knobs=dict(train_param_dtype=jnp.bfloat16,
                   cfg_overrides={"attn_impl": "sparse"}, microbatches=4),
    ),
    "qwen.micro2": dict(
        arch="qwen2.5-3b", shape="train_4k",
        hypothesis=(
            "cumulative: micro4 halved collective bytes — if per-round "
            "fixed-size reductions dominate, 4→2 microbatches should halve "
            "them again (predict t_collective ~0.11s)"
        ),
        knobs=dict(train_param_dtype=jnp.bfloat16,
                   cfg_overrides={"attn_impl": "sparse"}, microbatches=2),
    ),
    "mixtral_train.base": dict(
        arch="mixtral-8x7b", shape="train_4k",
        hypothesis=(
            "baseline after the 2D-expert memory fix: weight gathers over "
            "'data' made train collective-bound (t_coll 3.66s)"
        ),
        knobs={},
    ),
    "mixtral_train.ep2d": dict(
        arch="mixtral-8x7b", shape="train_4k",
        hypothesis=(
            "shard expert d_model over 'model' and ff over 'data' instead: "
            "weights stay put and the contraction inserts activation "
            "all-reduces of (E,G,C,·) tiles — predicted cheaper than "
            "re-gathering 46B expert weights every microbatch"
        ),
        knobs=dict(rules=with_rules(
            DEFAULT_RULES, expert_embed="model", expert_mlp=("data",)
        )),
    ),
    # ------------------------------------------------------------------
    # Cell B: mixtral-8x7b × prefill_32k (worst useful ratio among
    # compute-bound cells: dense attention pays full 32k² despite SWA-4k)
    # ------------------------------------------------------------------
    "mixtral.base": dict(
        arch="mixtral-8x7b", shape="prefill_32k",
        hypothesis="baseline (dense flash attention computes all kv blocks then masks)",
        knobs={},
    ),
    "mixtral.sparseattn": dict(
        arch="mixtral-8x7b", shape="prefill_32k",
        hypothesis=(
            "SWA window 4096 over 32768 ctx: visible blocks ≈ (W+qc)/S ≈ 14% "
            "of the full grid → predict attention FLOPs ~7× down; total "
            "t_compute down by the attention share (~45% at 32k) and "
            "useful_ratio 0.54 → ~0.75"
        ),
        knobs=dict(cfg_overrides={"attn_impl": "sparse"}),
    ),
    # ------------------------------------------------------------------
    # Cell C: chatglm3-6b × decode_32k (paper-representative serving cell;
    # memory-bound: kv=2 padded to 16 → 8× KV-cache bloat per chip)
    # ------------------------------------------------------------------
    "chatglm3.base": dict(
        arch="chatglm3-6b", shape="decode_32k",
        hypothesis="baseline (KV heads padded 2→16 for clean TP sharding)",
        knobs={},
    ),
    "chatglm3.seqshard": dict(
        arch="chatglm3-6b", shape="decode_32k",
        hypothesis=(
            "keep native kv=2 and shard the cache SEQUENCE dim over 'model' "
            "instead of padding heads: per-chip cache bytes drop 8× "
            "(962GB→120GB global); the cross-shard softmax moves only "
            "(B,H,S) score tensors (~0.5GB global) over ICI → predict "
            "t_memory ~6-8× down, small t_collective increase"
        ),
        knobs=dict(
            cfg_overrides={"pad_kv_to_tp": False},
            rules=with_rules(
                SERVE_RULES, cache_seq="model", cache_heads=None, seq=None
            ),
        ),
    ),
    "mixtral.cf1": dict(
        arch="mixtral-8x7b", shape="prefill_32k",
        hypothesis=(
            "cumulative: GShard capacity factor 1.25 inflates expert FLOPs "
            "25%; cf=1.0 trades marginal token drops for ~14% of the MLP "
            "share of t_compute (quality tradeoff recorded, not free)"
        ),
        knobs=dict(cfg_overrides={"attn_impl": "sparse", "capacity_factor": 1.0}),
    ),
    "chatglm3.f8kv": dict(
        arch="chatglm3-6b", shape="decode_32k",
        hypothesis=(
            "cumulative w/ seqshard: store the KV cache in float8_e4m3fn "
            "(upcast after the HBM read) — cache bytes halve again → predict "
            "t_memory ~3.5e-4 (params now a visible fraction)"
        ),
        knobs=dict(
            cfg_overrides={"pad_kv_to_tp": False, "cache_dtype": "float8_e4m3fn"},
            rules=with_rules(
                SERVE_RULES, cache_seq="model", cache_heads=None, seq=None
            ),
        ),
    ),
    "chatglm3.seqshard.sparse": dict(
        arch="chatglm3-6b", shape="decode_32k",
        hypothesis=(
            "cumulative: sparse-attn flag is decode-neutral (decode attends "
            "one token) — control variant to confirm no regression"
        ),
        knobs=dict(
            cfg_overrides={"pad_kv_to_tp": False, "attn_impl": "sparse"},
            rules=with_rules(
                SERVE_RULES, cache_seq="model", cache_heads=None, seq=None
            ),
        ),
    ),
}


def run_variant(name: str, spec: dict) -> dict:
    mesh = make_production_mesh(multi_pod=False)
    chips = int(np.prod(list(mesh.shape.values())))
    knobs = dict(spec["knobs"])
    t0 = time.time()
    cell = build_cell(
        spec["arch"], spec["shape"], mesh,
        knobs.pop("rules", None),
        microbatches=knobs.pop("microbatches", 8),
        cfg_overrides=knobs.pop("cfg_overrides", None),
        train_param_dtype=knobs.pop("train_param_dtype", jnp.float32),
    )
    assert not knobs, knobs
    with mesh:
        lowered = cell.lower()
        compiled = lowered.compile()
        ca = compiled.cost_analysis() or {}
        coll = collective_bytes_from_hlo(compiled.as_text())
        jaxpr_flops = count_jaxpr_flops(
            cell.fn.__wrapped__ if hasattr(cell.fn, "__wrapped__") else cell.fn,
            *cell.args,
        )
    terms = compute_roofline(
        arch=spec["arch"], shape=spec["shape"], mesh="single", chips=chips,
        hlo_flops_raw=float(ca.get("flops", 0.0)),
        hlo_bytes_raw=float(ca.get("bytes accessed", 0.0)),
        jaxpr_flops=jaxpr_flops,
        model_bytes=cell.model_bytes,
        coll_bytes_raw=float(coll.raw_bytes),
        coll_bytes=float(coll.global_bytes),
        model_flops=cell.model_flops,
    )
    row = dict(
        variant=name,
        arch=spec["arch"],
        shape=spec["shape"],
        hypothesis=spec["hypothesis"],
        wall_s=round(time.time() - t0, 1),
        t_compute=terms.t_compute,
        t_memory=terms.t_memory,
        t_collective=terms.t_collective,
        bottleneck=terms.bottleneck,
        useful_ratio=terms.useful_ratio,
        roofline_fraction=terms.roofline_fraction,
        jaxpr_flops=terms.jaxpr_flops,
        model_bytes=terms.model_bytes,
        coll_bytes=terms.coll_bytes,
    )
    return row


def main(argv=None) -> int:
    wanted = (argv or sys.argv[1:]) or list(VARIANTS)
    os.makedirs("results", exist_ok=True)
    done = set()
    if os.path.exists(OUT):
        for line in open(OUT):
            try:
                done.add(json.loads(line)["variant"])
            except Exception:
                pass
    for name in wanted:
        if name in done:
            print(f"[skip-done] {name}")
            continue
        print(f"[variant] {name}: {VARIANTS[name]['hypothesis'][:100]}...", flush=True)
        try:
            row = run_variant(name, VARIANTS[name])
        except Exception as e:  # noqa: BLE001
            import traceback

            traceback.print_exc()
            row = dict(variant=name, error=f"{type(e).__name__}: {e}")
        with open(OUT, "a") as f:
            f.write(json.dumps(row) + "\n")
        if "error" not in row:
            print(
                f"  t_comp={row['t_compute']:.3e} t_mem={row['t_memory']:.3e} "
                f"t_coll={row['t_collective']:.3e} bneck={row['bottleneck']} "
                f"useful={row['useful_ratio']:.3f} frac={row['roofline_fraction']:.3f}"
            )
    return 0


if __name__ == "__main__":
    sys.exit(main())
