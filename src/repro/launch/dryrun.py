import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import (device count locks on
first init) and are deliberately NOT set globally — smoke tests and benches
see the real single CPU device.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi    # 2-pod only
    PYTHONPATH=src python -m repro.launch.dryrun --out results/dryrun.jsonl

Each cell appends one JSON line (restartable: existing (arch, shape, mesh)
rows are skipped unless --force).  Row contents: memory_analysis,
cost_analysis flops/bytes, trip-corrected jaxpr FLOPs, HLO collective bytes
(raw + corrected), analytic roofline terms, and the dominant bottleneck.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import numpy as np

from repro.analysis.flops import count_jaxpr_flops
from repro.analysis.hlo import collective_bytes_from_hlo
from repro.analysis.roofline import compute_roofline
from repro.configs.base import ARCH_IDS, get_config, shape_cells
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

DEFAULT_OUT = "results/dryrun.jsonl"


def run_cell(arch: str, shape_name: str, mesh_name: str, rules=None, microbatches: int = 8) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    cell = build_cell(arch, shape_name, mesh, rules, microbatches=microbatches)
    row = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "kind": cell.kind,
    }
    if cell.kind == "skip":
        row["skip_reason"] = cell.skip_reason
        return row

    with mesh:
        lowered = cell.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
        # trip-corrected analytic FLOPs from the pre-lowering jaxpr
        jaxpr_flops = count_jaxpr_flops(
            cell.fn.__wrapped__ if hasattr(cell.fn, "__wrapped__") else cell.fn,
            *cell.args,
        )

    # DCN share: on the multi-pod mesh, collectives that touch the pod axis
    # cross DCN.  Approximation: training gradient reduce crosses pods once
    # per step (2·P bytes ring-share); serving decode crosses none.
    dcn_bytes = 0.0
    if mesh_name == "multi" and cell.kind == "train":
        total_p = sum(
            float(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(cell.args[0])
        )
        dcn_bytes = 2.0 * total_p * 4.0 / 2  # ring all-reduce, 2 pods, f32 grads

    terms = compute_roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops_raw=float(ca.get("flops", 0.0)),
        hlo_bytes_raw=float(ca.get("bytes accessed", 0.0)),
        jaxpr_flops=jaxpr_flops,
        model_bytes=cell.model_bytes,
        coll_bytes_raw=float(coll.raw_bytes),
        coll_bytes=float(coll.global_bytes),
        dcn_bytes=dcn_bytes,
        model_flops=cell.model_flops,
    )
    row.update(
        {
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            "cost_analysis": {
                "flops": float(ca.get("flops", 0.0)),
                "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            },
            "collectives": {
                "raw_bytes": coll.raw_bytes,
                "corrected_bytes": coll.corrected_bytes,
                "global_bytes": coll.global_bytes,
                "by_kind": coll.by_kind,
                "ops": coll.ops,
            },
            "roofline": {
                k: v
                for k, v in dataclasses.asdict(terms).items()
                if k not in ("arch", "shape", "mesh", "extra")
            },
        }
    )
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: arch's set)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    args = ap.parse_args(argv)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    archs = [args.arch] if args.arch else ARCH_IDS
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else [s.name for s in shape_cells(cfg)]
        # always record the skip rows for non-subquadratic long_500k
        if not args.shape and not cfg.subquadratic:
            shapes.append("long_500k")
        for shape in shapes:
            for mesh_name in meshes:
                key = (arch, shape, mesh_name)
                if key in done:
                    print(f"[skip-done] {key}")
                    continue
                print(f"[cell] {arch} × {shape} × {mesh_name} ...", flush=True)
                t0 = time.time()
                try:
                    row = run_cell(arch, shape, mesh_name, microbatches=args.microbatches)
                    status = row.get("kind")
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    row = {
                        "arch": arch,
                        "shape": shape,
                        "mesh": mesh_name,
                        "kind": "error",
                        "error": f"{type(e).__name__}: {e}",
                    }
                    status = "ERROR"
                    failures += 1
                row["wall_s"] = round(time.time() - t0, 1)
                with open(args.out, "a") as f:
                    f.write(json.dumps(row) + "\n")
                print(f"  -> {status} in {row['wall_s']}s", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
