"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --reduced \
        --steps 20 --batch 8 --seq 128

``--reduced`` (default) trains the smoke-scale variant on the local device
mesh; without it the launcher expects a real TPU slice matching
``make_production_mesh()`` (on CPU it will refuse — the full configs are
exercised via the dry-run).  Checkpoints are committed through the catalog
every ``--ckpt-every`` steps and training resumes from the latest snapshot.
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, reduced as make_reduced
from repro.data.pipeline import SyntheticTokens
from repro.iceberg.catalog import RestCatalog
from repro.lakehouse.objectstore import ObjectStore
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.model import build_model
from repro.training.checkpoint import CheckpointManager
from repro.training.train_loop import TrainStepConfig, init_train_state, make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--warehouse", default=None, help="object-store root (default: tmp)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
        mesh = make_debug_mesh(1, 1)
    else:
        mesh = make_production_mesh()
    model = build_model(cfg, tp=mesh.shape.get("model", 1))
    step, sh = make_train_step(
        model, mesh,
        cfg=TrainStepConfig(microbatches=args.microbatches, lr=args.lr, remat=True),
    )
    with mesh:
        params, opt = init_train_state(model, mesh)
    print(f"[train] {args.arch} ({'reduced' if args.reduced else 'FULL'}): "
          f"{model.param_count()/1e6:.1f}M params on mesh {dict(mesh.shape)}")

    store = ObjectStore(args.warehouse or tempfile.mkdtemp())
    mgr = CheckpointManager(RestCatalog(store), async_save=True)
    start = 0
    try:
        restored, start = mgr.restore({"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        print(f"[train] resumed from committed step {start}")
        start += 1
    except FileNotFoundError:
        pass

    data = SyntheticTokens(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        num_codebooks=cfg.num_codebooks, seed=0,
    )
    t0 = time.time()
    with mesh:
        for i in range(start, args.steps):
            ids, labels = data.batch(i)
            params, opt, m = step(params, opt, jnp.asarray(ids), jnp.asarray(labels))
            if i % 5 == 0 or i == args.steps - 1:
                tok_s = args.batch * args.seq * (i - start + 1) / (time.time() - t0)
                print(f"  step {i:4d} loss {float(m['loss']):.3f} "
                      f"gnorm {float(m['grad_norm']):.2f} ({tok_s:.0f} tok/s)")
            if args.ckpt_every and i and i % args.ckpt_every == 0:
                mgr.save(i, {"params": params, "opt": opt}, metrics={"loss": m["loss"]})
    mgr.wait()
    print("[train] done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
