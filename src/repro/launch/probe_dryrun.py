import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Dry-run of the paper's own workload: a 10^9-vector probe on the mesh.

Bonus rows beyond the 40 assigned cells: the Stage-A+C distributed probe
(§6) at the paper's §9 configuration — 10^9 vectors × 768 d, R=64, k=100 —
device-resident, one ~3.9M-vector shard per chip (256 shards over
(data, model)).  Lower + compile + roofline on both meshes.

    PYTHONPATH=src python -m repro.launch.probe_dryrun
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.flops import count_jaxpr_flops
from repro.analysis.hlo import collective_bytes_from_hlo
from repro.analysis.roofline import compute_roofline
from repro.launch.mesh import make_production_mesh
from repro.serving.device_index import DeviceAnnIndex, make_probe_fn

OUT = "results/probe_dryrun.jsonl"

N = 1_000_000_000
D = 768
R = 64
L = 100
K = 100
Q = 64  # concurrent queries per probe step


def run(mesh_name: str) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    shard_axes = ("data", "model")
    n_shards = mesh.shape["data"] * mesh.shape["model"]
    cap = 1 << int(np.ceil(np.log2(N / n_shards)))  # 4194304
    probe = make_probe_fn(mesh, k=K, L=L, metric="l2", oversample=2, shard_axes=shard_axes)
    idx = DeviceAnnIndex.abstract(n_shards, cap, D, R, dtype=jnp.bfloat16)
    queries = jax.ShapeDtypeStruct((Q, D), jnp.float32)
    t0 = time.time()
    with mesh:
        fn = jax.jit(probe, in_shardings=(idx.shardings(mesh, shard_axes), None))
        lowered = fn.lower(idx, queries)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        coll = collective_bytes_from_hlo(compiled.as_text())
        jaxpr_flops = count_jaxpr_flops(probe, idx, queries)
    # a fundamental asymmetry vs the LM cells: the beam search while_loop's
    # trip count is data-dependent (≈ L expansions); jaxpr counts it once,
    # so scale by the expected expansions for the roofline.
    expansions = int(1.3 * L) + 8
    jaxpr_flops_expected = jaxpr_flops * expansions
    # useful work ~ distance computations: Q × expansions × R nbrs × 2D flops
    model_flops = Q * expansions * R * 2.0 * D * n_shards
    # memory: each expansion gathers R neighbor vectors (bf16) + adjacency
    model_bytes = Q * expansions * R * (D * 2 + 4) * n_shards
    terms = compute_roofline(
        arch="ann-probe-1b", shape=f"probe_q{Q}_k{K}", mesh=mesh_name, chips=chips,
        hlo_flops_raw=float(ca.get("flops", 0.0)),
        hlo_bytes_raw=float(ca.get("bytes accessed", 0.0)),
        jaxpr_flops=jaxpr_flops_expected,
        model_bytes=model_bytes,
        coll_bytes_raw=float(coll.raw_bytes),
        coll_bytes=float(coll.global_bytes),
        model_flops=model_flops,
    )
    return {
        "arch": "ann-probe-1b",
        "shape": f"probe_q{Q}_k{K}",
        "mesh": mesh_name,
        "kind": "probe",
        "wall_s": round(time.time() - t0, 1),
        "index": {"N": N, "D": D, "R": R, "shards": n_shards, "cap": cap,
                  "hbm_per_chip_gb": round(cap * (D * 2 + R * 4 + 4) / 1e9, 2)},
        "memory_analysis": {
            "argument_bytes": ma.argument_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
        },
        "collectives": {"raw_bytes": coll.raw_bytes, "global_bytes": coll.global_bytes},
        "roofline": {
            "t_compute": terms.t_compute,
            "t_memory": terms.t_memory,
            "t_collective": terms.t_collective,
            "bottleneck": terms.bottleneck,
            "note": "per-probe-step (64 queries); while-loop scaled by expected expansions",
        },
    }


def main():
    os.makedirs("results", exist_ok=True)
    with open(OUT, "w") as f:
        for mesh_name in ("single", "multi"):
            print(f"[probe-dryrun] {mesh_name} ...", flush=True)
            row = run(mesh_name)
            f.write(json.dumps(row) + "\n")
            print(
                f"  ok in {row['wall_s']}s  hbm/chip={row['index']['hbm_per_chip_gb']}GB "
                f"bneck={row['roofline']['bottleneck']} "
                f"t_mem={row['roofline']['t_memory']:.2e}s"
            )


if __name__ == "__main__":
    main()
