"""Per-cell dry-run specs: step functions + ShapeDtypeStruct inputs.

``build_cell(arch, shape, mesh, rules)`` returns a :class:`CellSpec` whose
``lower()`` produces the jax.jit lowering for the cell's step function:

- train_4k     → ``train_step``   (CE + AdamW, microbatched, remat)
- prefill_32k  → ``prefill_step``
- decode_32k / long_500k → ``serve_step`` (one token, full KV/state cache)

plus the analytic MODEL_FLOPS / traffic model used by the roofline.
All inputs are ShapeDtypeStructs — nothing is allocated.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ShapeConfig, get_config, SHAPES
from repro.models.model import Model, build_model, param_shapes
from repro.models.sharding import DEFAULT_RULES, LogicalRules, with_rules
from repro.training.optimizer import AdamWState
from repro.training.train_loop import TrainStepConfig, make_train_step
from repro.serving.serve_loop import ServeConfig, make_serve_fns

SERVE_RULES = with_rules(
    DEFAULT_RULES,
    batch=("pod", "data"),
    cache_batch=("pod", "data"),
)


def _abstract(tree, dtype=None):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype), tree
    )


@dataclass
class CellSpec:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    model: Model
    kind: str
    fn: Callable  # the jit'd (but not yet lowered) step
    args: Tuple  # ShapeDtypeStruct inputs
    model_flops: float
    model_bytes: float
    skip_reason: Optional[str] = None

    def lower(self):
        return self.fn.lower(*self.args)


def _active_params(cfg: ModelConfig, model: Model) -> Tuple[float, float]:
    """(total_params, active_non_embedding_params)."""
    shapes = param_shapes(model)
    total = 0.0
    active = 0.0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        total += n
        keys = "/".join(str(getattr(p, "key", "")) for p in path)
        if "embed" in keys or "lm_head" in keys:
            continue
        if "moe" in keys and "router" not in keys:
            n = n * cfg.top_k / max(cfg.num_experts, 1)
        active += n
    return total, active


def _attn_flops_train(cfg: ModelConfig, B: int, S: int, kv_eff: int) -> float:
    if cfg.attention == "none":
        return 0.0
    # qk^T and a·v, causal → /2; fwd+bwd ≈ 3×fwd
    window = cfg.window if cfg.attention == "swa" else S
    eff = min(window, S)
    per_layer = 2.0 * B * S * eff * cfg.num_heads * cfg.head_dim * 2 / 2
    n_attn_layers = cfg.num_layers if not cfg.shared_attn_every else cfg.num_layers // cfg.shared_attn_every
    return 3.0 * per_layer * n_attn_layers


def _attn_flops_decode(cfg: ModelConfig, B: int, S_ctx: int) -> float:
    if cfg.attention == "none":
        return 0.0
    window = cfg.window if cfg.attention == "swa" else S_ctx
    eff = min(window, S_ctx)
    n_attn_layers = cfg.num_layers if not cfg.shared_attn_every else cfg.num_layers // cfg.shared_attn_every
    return 2.0 * B * eff * cfg.num_heads * cfg.head_dim * 2 * n_attn_layers


def _cache_bytes(model: Model, B: int, max_len: int) -> float:
    cache = jax.eval_shape(lambda: model.init_cache(B, max_len))
    return float(
        sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree_util.tree_leaves(cache))
    )


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    rules: Optional[LogicalRules] = None,
    *,
    microbatches: int = 8,
    remat: bool = True,
    cfg_overrides: Optional[dict] = None,
    train_param_dtype=jnp.float32,
) -> CellSpec:
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    tp = mesh.shape.get("model", 1)
    model = build_model(cfg, tp=tp)
    total_p, active_p = _active_params(cfg, model)

    if shape.name == "long_500k" and not cfg.subquadratic:
        return CellSpec(
            arch=arch, shape=shape, cfg=cfg, model=model, kind="skip",
            fn=None, args=(), model_flops=0.0, model_bytes=0.0,
            skip_reason="pure full-attention arch: 500k KV cache is quadratic-cost; "
                        "skipped per assignment (DESIGN.md §4)",
        )

    B, S = shape.global_batch, shape.seq_len
    ids_extra = (cfg.num_codebooks,) if cfg.num_codebooks else ()

    if shape.kind == "train":
        rules = rules or DEFAULT_RULES
        step, sh = make_train_step(
            model, mesh, rules,
            TrainStepConfig(microbatches=microbatches, remat=remat),
        )
        pshapes = _abstract(param_shapes(model), train_param_dtype)
        opt = AdamWState(
            m=_abstract(pshapes, jnp.float32),
            v=_abstract(pshapes, jnp.float32),
            step=jax.ShapeDtypeStruct((), jnp.int32),
        )
        ids = jax.ShapeDtypeStruct((B, S) + ids_extra, jnp.int32)
        # musicgen codebook streams share one backbone position: tokens = B·S
        tokens = B * S
        mf = 6.0 * active_p * tokens + _attn_flops_train(cfg, B, S, model.kv_eff)
        # traffic: params ×3 passes per microbatch (fwd, remat-fwd, bwd)
        # + optimizer m/v read+write + grads
        p_bytes = jnp.dtype(train_param_dtype).itemsize
        mb = (
            microbatches * 3.0 * total_p * p_bytes
            + total_p * (4 * 2 + 4 * 2 + 4 * 2)  # m,v rw + grads rw
            + tokens * cfg.d_model * cfg.num_layers * 4 * 2.0  # layer boundaries
        )
        return CellSpec(arch, shape, cfg, model, "train", step, (pshapes, opt, ids, ids), mf, mb)

    # serving cells: bf16 params.  Experts stay 1D (model) sharded when the
    # bf16 stack fits HBM that way (mixtral: 5.8 GB/chip) — the train-time 2D
    # rule exists for f32 masters + moments and would add FSDP-style gathers
    # to the serve path; dbrx (16.5 GB/chip at 1D) keeps 2D out of necessity.
    if rules is None:
        rules = SERVE_RULES
        if cfg.num_experts:
            total_p_, _ = _active_params(cfg, model)
            tp_ = mesh.shape.get("model", 1)
            if total_p_ * 2.0 / tp_ < 12e9:
                rules = with_rules(SERVE_RULES, expert_mlp="model")
    pshapes = _abstract(param_shapes(model), jnp.bfloat16)
    prefill_fn, decode_fn, _sample, sh = make_serve_fns(
        model, mesh, rules, ServeConfig(), batch_hint=B, max_len_hint=S
    )
    if shape.kind == "prefill":
        ids = jax.ShapeDtypeStruct((B, S) + ids_extra, jnp.int32)
        cache = _abstract(jax.eval_shape(lambda: model.init_cache(B, S)))
        tokens = B * S
        mf = 2.0 * active_p * tokens + _attn_flops_train(cfg, B, S, model.kv_eff) / 3.0
        mb = total_p * 2.0 + tokens * cfg.d_model * cfg.num_layers * 2 * 2.0 + _cache_bytes(model, B, S)
        return CellSpec(arch, shape, cfg, model, "prefill", prefill_fn, (pshapes, ids, cache), mf, mb)

    # decode
    ids = jax.ShapeDtypeStruct((B, 1) + ids_extra, jnp.int32)
    cache = _abstract(jax.eval_shape(lambda: model.init_cache(B, S)))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    mf = 2.0 * active_p * B + _attn_flops_decode(cfg, B, S)
    cache_b = _cache_bytes(model, B, S)
    # decode traffic: all params once (bf16) + cache read + small writes.
    # MoE dense-dispatch decode really does read every expert — honest.
    mb = total_p * 2.0 + cache_b
    return CellSpec(arch, shape, cfg, model, "decode", decode_fn, (pshapes, ids, cache, pos), mf, mb)
