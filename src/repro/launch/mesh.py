"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Shapes: one v5e pod = (data=16, model=16) = 256
chips; two pods = (pod=2, data=16, model=16) = 512 chips.  The ``pod`` axis
maps onto DCN; ``data``/``model`` map onto ICI.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "the dry-run launcher must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import"
        )
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests/examples)."""
    shape = (data, model)
    need = data * model
    return jax.make_mesh(shape, ("data", "model"), devices=jax.devices()[:need])
