"""Serving launcher: batched prefill+decode, optional retrieval augmentation.

    PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced \
        --batch 4 --prompt 32 --gen 32 [--retrieval]

``--retrieval`` builds a small Vamana corpus index on the fly and fuses the
kNN-LM probe into every decode step (the paper's index as a serving
feature).  Reduced configs run on the local device; full configs require a
real slice (the decode cells are exercised via the dry-run on CPU).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced as make_reduced
from repro.core.vamana import VamanaParams, build_vamana
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.model import build_model
from repro.serving.device_index import DeviceAnnIndex, make_probe_fn
from repro.serving.serve_loop import ServeConfig, make_serve_fns


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--knn-lambda", type=float, default=0.3)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = make_reduced(cfg)
        mesh = make_debug_mesh(1, 1)
    else:
        mesh = make_production_mesh()
    model = build_model(cfg, tp=mesh.shape.get("model", 1))
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt + args.gen
    rng = np.random.default_rng(0)

    probe = index = None
    if args.retrieval:
        head = np.asarray(params["lm_head"], np.float32)
        if head.ndim == 3:  # musicgen: use codebook 0's head space
            head = head[0]
        corpus_tokens = rng.integers(0, cfg.vocab_size, size=2000)
        corpus = head[:, corpus_tokens].T + 0.01 * rng.normal(
            size=(2000, cfg.d_model)
        ).astype(np.float32)
        g = build_vamana(corpus.astype(np.float32), VamanaParams(R=8, L=16),
                         passes=1, batch=256)
        index = DeviceAnnIndex.from_graphs([g], payloads=[corpus_tokens])
        probe = make_probe_fn(mesh, k=8, L=16)

    prefill, decode, sample, _ = make_serve_fns(
        model, mesh, cfg=ServeConfig(knn_lambda=args.knn_lambda if args.retrieval else 0.0),
        retrieval=probe, index_template=index,
        batch_hint=args.batch, max_len_hint=max_len,
    )
    ids_shape = (args.batch, args.prompt) + ((cfg.num_codebooks,) if cfg.num_codebooks else ())
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, size=ids_shape))
    cache = model.init_cache(args.batch, max_len)
    print(f"[serve] {args.arch}: batch={args.batch} prompt={args.prompt} "
          f"gen={args.gen} retrieval={'on' if args.retrieval else 'off'}")
    with mesh:
        t0 = time.perf_counter()
        logits, cache = prefill(params, prompts, cache)
        tok = sample(logits, jax.random.PRNGKey(0))
        t_prefill = time.perf_counter() - t0
        t0 = time.perf_counter()
        for t in range(args.prompt, max_len):
            step_args = (params, tok, cache, jnp.int32(t))
            if args.retrieval:
                logits, cache = decode(*step_args, index)
            else:
                logits, cache = decode(*step_args)
            tok = sample(logits, jax.random.PRNGKey(t))
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
    print(f"  prefill: {t_prefill*1e3:.0f} ms ({args.batch*args.prompt/t_prefill:.0f} tok/s)")
    print(f"  decode:  {t_decode/args.gen*1e3:.1f} ms/step "
          f"({args.batch*args.gen/t_decode:.0f} tok/s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
