"""Token data pipeline.

Two sources behind one iterator protocol:

- :class:`SyntheticTokens` — deterministic Zipf-ish token stream, seeded per
  (host, shard) so multi-host data parallelism reads disjoint streams without
  coordination (each host computes its own slice: the standard stateless
  "index-based" sharding that survives elastic restarts);
- :class:`TokenTableReader` — tokens stored *in the lakehouse*: a vparquet
  ``tokens`` column committed through the same Iceberg catalog as everything
  else, read with row-group granularity.  This is how the end-to-end example
  feeds training from table data, and how embedding extraction writes back.

Batches are (ids, labels) int32 arrays with labels = ids shifted left
(next-token prediction), -100 padding masked.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np

from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.vparquet import ColumnSpec, VParquetReader, VParquetWriter


@dataclass
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    batch_size: int
    num_codebooks: int = 0
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Stateless: batch(step) is identical across restarts (elasticity)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4099 + self.host_id * 7 + self.num_hosts
        )
        shape = (self.batch_size, self.seq_len + 1)
        if self.num_codebooks:
            shape = shape + (self.num_codebooks,)
        # Zipf-ish marginal over the vocab (heavier head, long tail)
        z = rng.zipf(1.3, size=shape)
        ids = np.minimum(z - 1, self.vocab_size - 1).astype(np.int32)
        return ids[:, :-1], ids[:, 1:]


TOKENS_SCHEMA = [ColumnSpec("tokens", "int32", 0)]


def write_token_table(
    store: ObjectStore, key: str, tokens: np.ndarray, rows_per_group: int = 65536
) -> int:
    w = VParquetWriter(TOKENS_SCHEMA)
    tokens = np.ascontiguousarray(tokens.reshape(-1), dtype=np.int32)
    for s in range(0, len(tokens), rows_per_group):
        w.write_row_group({"tokens": tokens[s : s + rows_per_group]})
    data = w.finish()
    store.put(key, data)
    return len(data)


@dataclass
class TokenTableReader:
    store: ObjectStore
    keys: list
    seq_len: int
    batch_size: int
    host_id: int = 0
    num_hosts: int = 1

    def __iter__(self):
        buf = np.empty(0, np.int32)
        need = self.batch_size * (self.seq_len + 1)
        for key in self.keys[self.host_id :: self.num_hosts] or self.keys:
            r = VParquetReader.from_store(self.store, key)
            for rg in range(r.num_row_groups):
                buf = np.concatenate([buf, r.read_column("tokens", [rg])])
                while len(buf) >= need:
                    chunk, buf = buf[:need], buf[need:]
                    ids = chunk.reshape(self.batch_size, self.seq_len + 1)
                    yield ids[:, :-1], ids[:, 1:]
