"""Data pipeline: deterministic synthetic + lakehouse-backed token streams."""

from repro.data.pipeline import SyntheticTokens, TokenTableReader, write_token_table  # noqa: F401
