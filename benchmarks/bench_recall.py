"""Paper §9.3/§10: the independent-shard recall trade-off.

Recall@10 as a function of (a) shard count at fixed oversampling, and
(b) oversampling factor at fixed shards — quantifying Principle 1's loss and
its recovery by oversampling + exact rerank.  Paper projects 0.95–0.99
recall at oversample 4.
"""

import numpy as np

from benchmarks.common import clustered, emit
from repro.core.kmeans import assign, train_kmeans
from repro.core.vamana import VamanaParams, brute_force_topk, build_vamana, recall_at_k


def main() -> None:
    rng = np.random.default_rng(0)
    D = 64
    X = clustered(rng, 24_000, D, n_clusters=48)
    Q = X[rng.choice(len(X), 24)] + 0.05 * rng.normal(size=(24, D)).astype(np.float32)
    _, truth = brute_force_topk(X, Q, 10)

    def sharded_recall(n_shards: int, oversample: int) -> float:
        cents, _ = train_kmeans(X[:8000], n_shards * 4, iters=8, seed=1)
        part = assign(X, cents)
        shard_of = part % n_shards  # simple partition->shard fold
        merged = []
        graphs = []
        id_maps = []
        for s in range(n_shards):
            sel = np.flatnonzero(shard_of == s)
            graphs.append(
                build_vamana(X[sel], VamanaParams(R=24, L=48), passes=1, batch=256)
            )
            id_maps.append(sel)
        for qi in range(len(Q)):
            cands = []
            for g, ids in zip(graphs, id_maps):
                k_local = min(10 * oversample, g.n)
                d, i = g.search(Q[qi : qi + 1], k_local)
                for dd, ii in zip(d[0], i[0]):
                    if np.isfinite(dd):
                        cands.append((dd, ids[ii]))
            cands.sort()
            merged.append([i for _, i in cands[:10]])
        return recall_at_k(np.asarray(merged), truth)

    base = sharded_recall(1, 4)
    emit("recall.shards_1", 0.0, f"recall_{base:.3f}")
    for n_shards in (2, 4):
        r = sharded_recall(n_shards, 4)
        emit(f"recall.shards_{n_shards}", 0.0,
             f"recall_{r:.3f}_loss_vs_global_{base - r:+.3f}_paper_band_0.95_0.99")
    for ov in (1, 2, 4):
        r = sharded_recall(4, ov)
        emit(f"recall.oversample_{ov}", 0.0, f"recall_{r:.3f}")


if __name__ == "__main__":
    main()
