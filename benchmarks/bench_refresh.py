"""Paper §7: incremental refresh — insert rate, tombstoning, rebuild trigger.

Paper claims: ~200 µs/insert at 768 d (AVX2); a 10^6-vector batch over 4
executors ≈ 50 s of graph compute; tombstone ratio drives per-shard rebuild
above 20 %.
"""

import time

import numpy as np

from benchmarks.common import clustered, emit, make_cluster
from repro.core.vamana import VamanaParams, build_vamana
from repro.lakehouse.table import LakehouseTable
from repro.runtime.coordinator import IndexConfig


def main() -> None:
    rng = np.random.default_rng(0)
    # -- raw greedy-insert rate (graph mutation only, batched) --------------
    D = 96
    X = clustered(rng, 16_000, D)
    g = build_vamana(X, VamanaParams(R=24, L=48), passes=1, batch=256)
    Y = clustered(rng, 2_048, D)
    t0 = time.perf_counter()
    g.insert_batch(Y, batch=256)
    dt = time.perf_counter() - t0
    emit("refresh.greedy_insert", dt / len(Y) * 1e6,
         f"inserts_per_sec_{len(Y)/dt:.0f}_paper_200us_per_insert_avx2")

    # -- end-to-end REFRESH INDEX -------------------------------------------
    c = make_cluster(4)
    t = LakehouseTable(c.catalog, "bench")
    t.create(dim=D)
    t.append_vectors(X, num_files=16, rows_per_group=1024)
    c.coordinator.create_index(
        "bench", IndexConfig(name="idx", R=24, L=48, partitions_per_shard=4,
                             build_passes=1, build_batch=256),
    )
    t.append_vectors(Y, num_files=2, file_prefix="delta")
    doomed = t.current_files()[0].path
    t.delete_files([doomed])
    rr = c.coordinator.refresh_index("bench", "idx")
    emit("refresh.end_to_end", rr.seconds * 1e6,
         f"inserted_{rr.inserted}_tombstoned_{rr.tombstoned}_rebuilt_{rr.shards_rebuilt}")

    # -- tombstone-ratio rebuild trigger (paper §7.3: >20%) ------------------
    files = [f.path for f in t.current_files()]
    t.delete_files(files[: len(files) // 2])
    rr2 = c.coordinator.refresh_index("bench", "idx")
    emit("refresh.rebuild_trigger", rr2.seconds * 1e6,
         f"tombstoned_{rr2.tombstoned}_shards_rebuilt_{rr2.shards_rebuilt}_threshold_0.20")


if __name__ == "__main__":
    main()
