"""Paper §9.2: distributed build cost.

Measures stage timings + per-executor build throughput at measurable scale;
derives the projected billion-vector build time using the paper's hardware
model (the graph build dominates; throughput scales linearly with
executors — Principle 1).
"""

import numpy as np

from benchmarks.common import clustered, emit, make_cluster
from repro.lakehouse.table import LakehouseTable
from repro.runtime.coordinator import IndexConfig


def main() -> None:
    rng = np.random.default_rng(0)
    c = make_cluster(4)
    t = LakehouseTable(c.catalog, "bench")
    D = 64
    t.create(dim=D)
    n = 32_000
    X = clustered(rng, n, D)
    t.append_vectors(X, num_files=16, rows_per_group=1024)
    rep = c.coordinator.create_index(
        "bench",
        IndexConfig(name="idx", R=24, L=48, pq_m=8, pq_nbits=8,
                    partitions_per_shard=4, build_passes=1, build_batch=256),
    )
    total = rep.stage0_seconds + rep.stage1_seconds + rep.stage2_seconds
    emit("build.stage0_sample_kmeans", rep.stage0_seconds * 1e6, f"frac_{rep.stage0_seconds/total:.2f}")
    emit("build.stage1_shard_build", rep.stage1_seconds * 1e6, f"frac_{rep.stage1_seconds/total:.2f}")
    emit("build.stage2_assemble_commit", rep.stage2_seconds * 1e6, f"frac_{rep.stage2_seconds/total:.2f}")
    per_exec = n / 4 / rep.stage1_seconds
    emit(
        "build.throughput",
        rep.stage1_seconds / n * 1e6,
        f"vectors_per_sec_per_executor_{per_exec:.0f}",
    )
    # linear-scaling check (Principle 1): rebuild with 2 executors
    c2 = make_cluster(2)
    t2 = LakehouseTable(c2.catalog, "bench")
    t2.create(dim=D)
    t2.append_vectors(X, num_files=16, rows_per_group=1024)
    rep2 = c2.coordinator.create_index(
        "bench",
        IndexConfig(name="idx", R=24, L=48, pq_m=8, pq_nbits=8,
                    partitions_per_shard=4, build_passes=1, build_batch=256),
    )
    speedup = rep2.stage1_seconds / rep.stage1_seconds
    emit("build.scaling_2to4_executors", rep2.stage1_seconds * 1e6,
         f"stage1_time_ratio_{speedup:.2f}_ideal_2.0")


if __name__ == "__main__":
    main()
