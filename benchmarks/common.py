"""Shared benchmark scaffolding: timing, CSV rows, cluster factory."""

from __future__ import annotations

import tempfile
import time
from contextlib import contextmanager

import numpy as np


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


@contextmanager
def timed():
    t0 = time.perf_counter()
    box = {}
    yield box
    box["s"] = time.perf_counter() - t0


def clustered(rng, n, dim, n_clusters=32, scale=4.0):
    centers = rng.normal(size=(n_clusters, dim)) * scale
    per = n // n_clusters
    X = np.concatenate(
        [c + rng.normal(size=(per, dim)) for c in centers]
    ).astype(np.float32)
    rng.shuffle(X)
    return X


def make_cluster(num_executors=4):
    from repro.runtime.cluster import make_local_cluster

    return make_local_cluster(tempfile.mkdtemp(), num_executors=num_executors)
