"""Paper Table 1: index sizes at 10^9 vectors × 768 dims.

Measures the *actual* bytes-per-vector of our blob layouts at a small scale,
then extrapolates to the paper's configuration and checks the paper's
claimed sizes.  Paper values: centroid ~30 MB, IVF-PQ ~16 GB, HNSW ~60 GB,
DiskANN/Vamana (R=64) ~250 GB (with vectors) / ~60 GB (lean).
"""

import numpy as np

from benchmarks.common import clustered, emit, timed
from repro.core.blobs import ShardLocationMap, encode_shard_blob
from repro.core.centroid_index import CentroidIndex
from repro.core.pq import encode, train_pq
from repro.core.vamana import VamanaParams, build_vamana


def main() -> None:
    rng = np.random.default_rng(0)
    D = 768
    N_paper, F_paper = 1e9, 1e4
    PQ_M = 48

    # -- centroid index (analytic structure is exact: header + N entries) ---
    ci = CentroidIndex(
        centroids=rng.normal(size=(100, D)).astype(np.float32),
        max_distances=np.ones(100, np.float32),
        file_paths=[f"data/file-{i:05d}.vpq" for i in range(100)],
    )
    with timed() as t:
        blob = ci.to_blob()
    per_file = len(blob) / 100
    total_mb = per_file * F_paper / 1e6
    emit("table1.centroid_index", t["s"] * 1e6, f"projected_{total_mb:.1f}MB_paper_30MB")

    # -- Vamana shard blob: measure bytes/vector at 20k, extrapolate --------
    n = 20_000
    X = clustered(rng, n, 64)  # dim-independent parts measured at dim 64
    g = build_vamana(X, VamanaParams(R=32, L=48), passes=1, batch=256)
    pq = train_pq(X, m=8, nbits=8, iters=4)
    g.attach_pq(pq, encode(pq, X))
    loc = ShardLocationMap(
        [f"f{i}" for i in range(8)],
        (np.arange(n) % 8).astype(np.uint32),
        (np.arange(n) % 16).astype(np.uint32),
        (np.arange(n) % 4096).astype(np.uint32),
    )
    with timed() as t:
        encode_shard_blob(g, loc, include_vectors=True)
    lean = encode_shard_blob(g, loc, include_vectors=False)
    # measured structural bytes/vector (graph + codes + locmap), minus vectors
    structural = len(lean) / n  # codes(m=8) + adjacency(R=32) + locmap
    # paper params: R=64 (≈2× adjacency), m=48 codes
    adj_per_vec = (len(lean) - n * 8 - len(loc.file_paths) * 8) / n
    paper_struct = structural + (48 - 8) + adj_per_vec  # R=64 ≈ 2× R=32 adjacency
    lean_total_gb = paper_struct * N_paper / 1e9
    full_total_gb = (paper_struct + D * 4) * N_paper / 1e9
    emit(
        "table1.vamana_full",
        t["s"] * 1e6,
        f"projected_{full_total_gb:.0f}GB_paper_~1000GB_total_4shards_250GB_each",
    )
    emit(
        "table1.vamana_lean",
        0.0,
        f"projected_{lean_total_gb:.0f}GB_paper_240GB_total_4shards_60GB_each",
    )
    # -- PQ in-memory footprint (paper §9.2: 12 GB per 250M shard) ----------
    pq_gb = 2.5e8 * PQ_M / 1e9
    emit("table1.pq_codes_per_shard", 0.0, f"analytic_{pq_gb:.0f}GB_paper_12GB")


if __name__ == "__main__":
    main()
