"""Paper Table 2: query cost by strategy (no index / centroid / DiskANN).

Measurable scale: ~32k vectors, 32 files, 4 executors.  Reports files
scanned, bytes read from the object store, cold/warm latency, and recall —
the same columns as the paper's table; the derived field carries the
probe-vs-scan reduction ratios.
"""

import time

import numpy as np

from benchmarks.common import clustered, emit, make_cluster
from repro.core.vamana import brute_force_topk
from repro.lakehouse.table import LakehouseTable
from repro.runtime.coordinator import IndexConfig


def main() -> None:
    rng = np.random.default_rng(0)
    c = make_cluster(4)
    t = LakehouseTable(c.catalog, "bench")
    D = 96
    t.create(dim=D)
    X = clustered(rng, 32_000, D, n_clusters=64)
    # cluster-correlated file layout: the paper's §10 states recall (and
    # centroid pruning) depend on the data-partition correlation; writing
    # shuffled files makes every file centroid ≈ the global mean and
    # centroid pruning degenerates to random file choice (measured:
    # recall 0.27 at n_probe=6 — a §10 validation).  Real ingest pipelines
    # cluster by time/key, which the sorted layout models.
    from repro.core.kmeans import assign, train_kmeans
    cents, _ = train_kmeans(X[:8192], 64, iters=8, seed=0)
    order = np.argsort(assign(X, cents), kind="stable")
    X = X[order]
    t.append_vectors(X, num_files=32, rows_per_group=512)
    c.coordinator.create_index(
        "bench",
        # paper-style search params: PQ traversal needs L ≳ 100 (DiskANN
        # ships L_search 100+; at L=48 PQ-guided beams misroute on
        # well-separated clusters — measured in EXPERIMENTS §1)
        IndexConfig(name="idx", R=24, L=128, pq_m=24, pq_nbits=8,
                    partitions_per_shard=4, build_passes=2, build_batch=256),
    )
    Q = X[rng.choice(len(X), 12)] + 0.05 * rng.normal(size=(12, D)).astype(np.float32)
    _, truth = brute_force_topk(X, Q, 10)
    vecs_all, locs_all = t.scan_vectors()
    truth_locs = [
        {(locs_all[i].file_path, locs_all[i].row_group_id, locs_all[i].row_offset) for i in row}
        for row in truth
    ]

    def recall(hits_lists):
        scores = [
            len({(h.file_path, h.row_group, h.row_offset) for h in hits} & tl) / len(tl)
            for hits, tl in zip(hits_lists, truth_locs)
        ]
        return float(np.mean(scores))

    results = {}
    for strat, kw in (
        ("scan", {}),
        ("centroid", {"n_probe": 6}),
        ("diskann", {}),
        ("diskann_fp", {"use_pq": False}),
    ):
        probe_strat = "diskann" if strat.startswith("diskann") else strat
        # cold: fresh executor caches
        for ex in c.executors:
            ex._l1.clear()
        t0 = time.perf_counter()
        pr_cold = c.coordinator.probe("bench", Q[:1], 10, strategy=probe_strat, **kw)
        cold_s = time.perf_counter() - t0
        # warm, PER QUERY (the paper's Table 2 counts files/bytes per query)
        hits, files, bytes_ = [], [], []
        t0 = time.perf_counter()
        for qi in range(len(Q)):
            pr = c.coordinator.probe("bench", Q[qi], 10, strategy=probe_strat, **kw)
            hits.append(pr.hits[0])
            files.append(pr.files_scanned)
            bytes_.append(pr.bytes_read)
        warm_s = (time.perf_counter() - t0) / len(Q)
        r = recall(hits)
        results[strat] = (float(np.mean(files)), float(np.mean(bytes_)))
        emit(
            f"table2.{strat}",
            warm_s * 1e6,
            f"files_per_query_{np.mean(files):.1f}_bytes_per_query_{np.mean(bytes_):.0f}"
            f"_cold_ms_{cold_s*1e3:.0f}_warm_ms_{warm_s*1e3:.0f}_recall_{r:.3f}",
        )
    emit(
        "table2.read_reduction",
        0.0,
        f"centroid_{results['scan'][1]/max(results['centroid'][1],1):.1f}x"
        f"_diskann_{results['scan'][1]/max(results['diskann'][1],1):.1f}x"
        f"_paper_25x_200x",
    )


if __name__ == "__main__":
    main()
