"""Paper Table 2: query cost by strategy (no index / centroid / DiskANN),
plus the batched multi-query pipeline (sequential probes vs probe_batch)
and the filtered-search path (attribute predicate vs brute-force oracle).

Measurable scale: ~32k vectors, 32 files, 4 executors.  Reports files
scanned, bytes read from the object store, cold/warm latency, and recall —
the same columns as the paper's table; the derived field carries the
probe-vs-scan reduction ratios.  The ``table2.batched`` row compares warm
per-query sequential probes against one ``probe_batch`` over the same
queries: the batch shares ≤ one shard fragment per shard and one rerank
wave, so its throughput must come out strictly higher.

The ``table2.overload`` row drives the multi-tenant serving tier at ~2x
measured capacity with two tenants (one abusive): admission control must
make the abuser absorb the rejections while the well-behaved tenant keeps
a >= 0.9 deadline hit-rate and the bounded queue holds.

``--tiny`` shrinks everything to a seconds-scale smoke run (used by
scripts/ci.sh to catch query-path regressions).

Every row is also written to ``--json`` (default ``BENCH_query_paths.json``)
as ``{"rows": {name: {"throughput_qps": ..., "recall": ..., ...}}}`` —
the machine-readable record scripts/check_bench.py gates CI on (absolute
floors plus >20% throughput / any-recall regression vs the committed
baseline in benchmarks/baselines/).
"""

import argparse
import json
import time

import numpy as np

from benchmarks.common import clustered, emit, make_cluster
from repro.core.vamana import brute_force_topk
from repro.lakehouse.table import LakehouseTable
from repro.runtime.coordinator import IndexConfig


def _best_of(fn, repeats: int = 3):
    """Best-of-N wall time for a warm code path.  Single-shot timings of
    ~10 ms sections swing well past the CI gate's 20% budget from scheduler
    and allocator noise alone; the minimum over a few repeats is the stable
    statistic (the true cost plus the least interference)."""
    best, out = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def main(tiny: bool = False, json_path: str = "BENCH_query_paths.json") -> None:
    rows: dict = {}  # row name -> machine-readable fields for check_bench

    rng = np.random.default_rng(0)
    if tiny:
        n_vec, n_files, n_exec, D, n_clusters = 2_048, 8, 2, 32, 16
        cfg = IndexConfig(name="idx", R=16, L=32, pq_m=8, pq_nbits=8,
                          partitions_per_shard=2, build_passes=1, build_batch=128)
        n_q, rows_per_group, n_probe = 8, 128, 3
    else:
        n_vec, n_files, n_exec, D, n_clusters = 32_000, 32, 4, 96, 64
        # paper-style search params: PQ traversal needs L ≳ 100 (DiskANN
        # ships L_search 100+; at L=48 PQ-guided beams misroute on
        # well-separated clusters — measured in EXPERIMENTS §1)
        cfg = IndexConfig(name="idx", R=24, L=128, pq_m=24, pq_nbits=8,
                          partitions_per_shard=4, build_passes=2, build_batch=256)
        n_q, rows_per_group, n_probe = 12, 512, 6
    c = make_cluster(n_exec)
    t = LakehouseTable(c.catalog, "bench")
    t.create(dim=D)
    X = clustered(rng, n_vec, D, n_clusters=n_clusters)
    # cluster-correlated file layout: the paper's §10 states recall (and
    # centroid pruning) depend on the data-partition correlation; writing
    # shuffled files makes every file centroid ≈ the global mean and
    # centroid pruning degenerates to random file choice (measured:
    # recall 0.27 at n_probe=6 — a §10 validation).  Real ingest pipelines
    # cluster by time/key, which the sorted layout models.
    from repro.core.kmeans import assign, train_kmeans
    cents, _ = train_kmeans(X[:8192], n_clusters, iters=8, seed=0)
    labels = assign(X, cents)
    order = np.argsort(labels, kind="stable")
    X = X[order]
    # attribute columns ride along: category follows the cluster layout
    # (zone maps get tight per-row-group tags), price is uncorrelated
    category = np.asarray([f"cat{int(l)}" for l in labels[order]])
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    t.append_vectors(
        X,
        num_files=n_files,
        rows_per_group=rows_per_group,
        attributes={"category": category, "price": price},
    )
    c.coordinator.create_index("bench", cfg)
    Q = X[rng.choice(len(X), n_q)] + 0.05 * rng.normal(size=(n_q, D)).astype(np.float32)
    _, truth = brute_force_topk(X, Q, 10)
    vecs_all, locs_all = t.scan_vectors()
    truth_locs = [
        {(locs_all[i].file_path, locs_all[i].row_group_id, locs_all[i].row_offset) for i in row}
        for row in truth
    ]

    def recall(hits_lists):
        scores = [
            len({(h.file_path, h.row_group, h.row_offset) for h in hits} & tl) / len(tl)
            for hits, tl in zip(hits_lists, truth_locs)
        ]
        return float(np.mean(scores))

    results = {}
    for strat, kw in (
        ("scan", {}),
        ("centroid", {"n_probe": n_probe}),
        ("diskann", {}),
        ("diskann_fp", {"use_pq": False}),
    ):
        probe_strat = "diskann" if strat.startswith("diskann") else strat
        # cold: fresh executor caches
        for ex in c.executors:
            ex._l1.clear()
        t0 = time.perf_counter()
        c.coordinator.probe("bench", Q[:1], 10, strategy=probe_strat, **kw)
        cold_s = time.perf_counter() - t0
        # warm, PER QUERY (the paper's Table 2 counts files/bytes per query)
        def _warm_loop():
            hits, files, bytes_ = [], [], []
            for qi in range(len(Q)):
                pr = c.coordinator.probe("bench", Q[qi], 10, strategy=probe_strat, **kw)
                hits.append(pr.hits[0])
                files.append(pr.files_scanned)
                bytes_.append(pr.bytes_read)
            return hits, files, bytes_

        loop_s, (hits, files, bytes_) = _best_of(_warm_loop)
        warm_s = loop_s / len(Q)
        r = recall(hits)
        results[strat] = (float(np.mean(files)), float(np.mean(bytes_)))
        emit(
            f"table2.{strat}",
            warm_s * 1e6,
            f"files_per_query_{np.mean(files):.1f}_bytes_per_query_{np.mean(bytes_):.0f}"
            f"_cold_ms_{cold_s*1e3:.0f}_warm_ms_{warm_s*1e3:.0f}_recall_{r:.3f}",
        )
        rows[f"table2.{strat}"] = {
            "throughput_qps": 1.0 / warm_s,
            "recall": r,
            "files_per_query": float(np.mean(files)),
            "bytes_per_query": float(np.mean(bytes_)),
        }
    emit(
        "table2.read_reduction",
        0.0,
        f"centroid_{results['scan'][1]/max(results['centroid'][1],1):.1f}x"
        f"_diskann_{results['scan'][1]/max(results['diskann'][1],1):.1f}x"
        f"_paper_25x_200x",
    )

    # ---- batched multi-query pipeline -----------------------------------
    # warm both paths (jit + caches already hot from the loop above), then
    # time B sequential probes against ONE probe_batch over the same block
    c.coordinator.probe_batch("bench", Q, 10, strategy="diskann")
    seq_s, seq_hits = _best_of(
        lambda: [
            c.coordinator.probe("bench", Q[qi], 10, strategy="diskann").hits[0]
            for qi in range(len(Q))
        ]
    )
    batch_s, pr_b = _best_of(
        lambda: c.coordinator.probe_batch("bench", Q, 10, strategy="diskann")
    )
    seq_qps = len(Q) / seq_s
    batch_qps = len(Q) / batch_s
    # parity check rides along: the batch must return the sequential hits
    same = all(
        [(h.file_path, h.row_group, h.row_offset) for h in a]
        == [(h.file_path, h.row_group, h.row_offset) for h in b]
        for a, b in zip(seq_hits, pr_b.hits)
    )
    emit(
        "table2.batched",
        batch_s / len(Q) * 1e6,
        f"B_{len(Q)}_seq_qps_{seq_qps:.1f}_batch_qps_{batch_qps:.1f}"
        f"_speedup_{batch_qps/seq_qps:.2f}x_fragments_{pr_b.probe_fragments}"
        f"_recall_{recall(pr_b.hits):.3f}_parity_{'ok' if same else 'BROKEN'}",
    )
    rows["table2.batched"] = {
        "throughput_qps": batch_qps,
        "seq_qps": seq_qps,
        "speedup": batch_qps / seq_qps,
        "recall": recall(pr_b.hits),
        "parity_ok": bool(same),
        "probe_fragments": pr_b.probe_fragments,
    }

    # ---- filtered probe vs brute-force post-filter oracle ----------------
    # High-selectivity predicate on the cluster-correlated attribute: the
    # zone map must prune shards (fewer fragments than the unfiltered
    # batch), and recall against the scan+post-filter oracle must stay
    # ≥ 0.95 — both gated by scripts/check_bench.py on the emitted JSON.
    target = f"cat{int(labels[order][len(X) // 2])}"
    flt = f"category = '{target}' AND price < 90"
    # warm both paths (first call pays one-time jit tracing of the masked
    # kernels; the row measures steady-state throughput, like the batched
    # row), then INTERLEAVE the oracle/filtered timing rounds so the
    # speedup-vs-oracle ratio check_bench gates on sees the same load in
    # numerator and denominator (wall clock alone swings >2x with ambient
    # load at this scale — measured live tripping the old baseline gate)
    c.coordinator.probe("bench", Q[:1], 10, strategy="scan", filter=flt)
    c.coordinator.probe_batch("bench", Q, 10, strategy="diskann", filter=flt)
    oracle_s = filt_s = float("inf")
    oracle = pr_f = None
    for _ in range(3):
        t0 = time.perf_counter()
        oracle = c.coordinator.probe("bench", Q, 10, strategy="scan", filter=flt)
        oracle_s = min(oracle_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        pr_f = c.coordinator.probe_batch("bench", Q, 10, strategy="diskann", filter=flt)
        filt_s = min(filt_s, time.perf_counter() - t0)
    truth_f = [
        {(h.file_path, h.row_group, h.row_offset) for h in hits} for hits in oracle.hits
    ]
    scores = [
        len({(h.file_path, h.row_group, h.row_offset) for h in hits} & tf) / max(len(tf), 1)
        for hits, tf in zip(pr_f.hits, truth_f)
    ]
    recall_f = float(np.mean(scores))
    emit(
        "table2.filtered",
        filt_s / len(Q) * 1e6,
        f"B_{len(Q)}_plan_{pr_f.filter_plan.replace(',', '+')}_sel_{pr_f.est_selectivity:.3f}"
        f"_pruned_{pr_f.shards_pruned}_fragments_{pr_f.probe_fragments}"
        f"_vs_unfiltered_{pr_b.probe_fragments}_oracle_ms_{oracle_s*1e3:.0f}"
        f"_filtered_ms_{filt_s*1e3:.0f}_recall_vs_oracle_{recall_f:.3f}",
    )
    rows["table2.filtered"] = {
        "throughput_qps": len(Q) / filt_s,
        "recall": recall_f,
        "filter_plan": pr_f.filter_plan,
        "est_selectivity": pr_f.est_selectivity,
        "shards_pruned": pr_f.shards_pruned,
        "probe_fragments": pr_f.probe_fragments,
        "unfiltered_fragments": pr_b.probe_fragments,
        "oracle_qps": len(Q) / oracle_s,
        "speedup_vs_oracle": oracle_s / filt_s,
    }

    # ---- heterogeneous-filter batch: per-query mask planes ----------------
    # Every query carries a DISTINCT predicate (8+ of them).  The legacy
    # executor path degrades to one masked-kernel pass per predicate group;
    # the mask-plane path answers the whole coalesced fragment with one
    # multi-mask call per shard (per scoring flavor).  Both paths are
    # measured in the same window (load cancels in the ratio) and must
    # return identical hits; check_bench gates: fewer kernel dispatches
    # than the per-group path, speedup > 1, recall vs oracle >= 0.95.
    hetero_filters = [
        f"price < {5 + (63 * i) // max(len(Q) - 1, 1)}" for i in range(len(Q))
    ]  # est selectivities ~0.05..0.68 — all mask-/prefilter-planned
    assert len(set(hetero_filters)) >= 8
    # warm both paths (masks cached, jit traced), then INTERLEAVE the
    # grouped/plane timing rounds: a load spike hits the same rounds of
    # both paths, so the speedup ratio check_bench hard-gates on stays
    # clean — two back-to-back best-of windows would let one unlucky
    # window fail the gate with no real regression (same reasoning as
    # bench_kernels' round-robin timing).
    def _hetero_probe():
        return c.coordinator.probe_batch(
            "bench", Q, 10, strategy="diskann", filter=hetero_filters
        )

    def _grouped(flag):
        for ex in c.executors:
            ex.force_group_loop = flag

    _hetero_probe()
    _grouped(True)
    _hetero_probe()
    grp_s = het_s = float("inf")
    pr_g = pr_h = None
    for _ in range(3):
        _grouped(True)
        t0 = time.perf_counter()
        pr_g = _hetero_probe()
        grp_s = min(grp_s, time.perf_counter() - t0)
        _grouped(False)
        t0 = time.perf_counter()
        pr_h = _hetero_probe()
        het_s = min(het_s, time.perf_counter() - t0)
    oracle_h = c.coordinator.probe_batch(
        "bench", Q, 10, strategy="scan", filter=hetero_filters
    )
    truth_h = [
        {(h.file_path, h.row_group, h.row_offset) for h in hits} for hits in oracle_h.hits
    ]
    recall_h = float(np.mean([
        len({(h.file_path, h.row_group, h.row_offset) for h in hits} & th) / max(len(th), 1)
        for hits, th in zip(pr_h.hits, truth_h)
    ]))
    parity_h = all(
        [(h.file_path, h.row_group, h.row_offset) for h in a]
        == [(h.file_path, h.row_group, h.row_offset) for h in b]
        for a, b in zip(pr_h.hits, pr_g.hits)
    )
    emit(
        "table2.filtered_hetero",
        het_s / len(Q) * 1e6,
        f"B_{len(Q)}_distinct_{len(set(hetero_filters))}"
        f"_dispatches_{pr_h.kernel_dispatches}_vs_grouped_{pr_g.kernel_dispatches}"
        f"_speedup_{grp_s/het_s:.2f}x_recall_vs_oracle_{recall_h:.3f}"
        f"_parity_{'ok' if parity_h else 'BROKEN'}",
    )
    rows["table2.filtered_hetero"] = {
        "throughput_qps": len(Q) / het_s,
        "grouped_qps": len(Q) / grp_s,
        "speedup_vs_grouped": grp_s / het_s,
        "recall": recall_h,
        "kernel_dispatches": pr_h.kernel_dispatches,
        "grouped_dispatches": pr_g.kernel_dispatches,
        "distinct_filters": len(set(hetero_filters)),
        "probe_fragments": pr_h.probe_fragments,
        "parity_ok": bool(parity_h),
    }

    # ---- mixed-flavor fragment: unified exact/PQ kernel -------------------
    # Alternating tight (prefilter-band -> exact flavor) and wide (mask-band
    # -> PQ-ADC flavor) predicates put BOTH scoring flavors in every
    # coalesced fragment.  The unified kernel answers such a fragment in
    # exactly ONE dispatch per shard; ``force_split_flavors`` re-enables the
    # PR-4 two-dispatch-per-shard path for comparison.  Dispatch counts,
    # recall, and parity come from full probe_batch runs; the speedup is
    # measured at the EXECUTOR fragment level (one shard's Stage A, both
    # modes interleaved in the same window) because a full probe wave rides
    # the scheduler's 5 ms poll quantum, which would drown the one-dispatch
    # delta in quantization noise.
    from repro.core.blobs import ROUTING_BLOB_TYPE, decode_routing_blob
    from repro.runtime import fragments as F
    from repro.runtime import planner

    mixed_filters = [
        f"price < {1 + i // 2}" if i % 2 == 0 else f"price < {55 + 3 * (i // 2)}"
        for i in range(len(Q))
    ]
    assert len(set(mixed_filters)) >= 8

    def _split(flag):
        for ex in c.executors:
            ex.force_split_flavors = flag

    def _mixed_probe():
        return c.coordinator.probe_batch(
            "bench", Q, 10, strategy="diskann", filter=mixed_filters
        )

    _mixed_probe()  # warm masks + jit (both modes share them)
    _split(True)
    pr_s = _mixed_probe()
    _split(False)
    pr_u = _mixed_probe()
    mixed_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        pr_u = _mixed_probe()
        mixed_s = min(mixed_s, time.perf_counter() - t0)
    # the plan must genuinely mix flavors (else the row gates nothing)
    flavors = {
        type(pr_u.plan.op_for(qi, sid)).__name__
        for qi in range(len(Q))
        for sid in pr_u.plan.ops[qi]
    }
    assert {"ExactScan", "PQScan"} <= flavors, flavors
    oracle_m = c.coordinator.probe_batch(
        "bench", Q, 10, strategy="scan", filter=mixed_filters
    )
    truth_m = [
        {(h.file_path, h.row_group, h.row_offset) for h in hits}
        for hits in oracle_m.hits
    ]
    recall_m = float(np.mean([
        len({(h.file_path, h.row_group, h.row_offset) for h in hits} & tm)
        / max(len(tm), 1)
        for hits, tm in zip(pr_u.hits, truth_m)
    ]))
    parity_m = all(
        [(h.file_path, h.row_group, h.row_offset, h.distance) for h in a]
        == [(h.file_path, h.row_group, h.row_offset, h.distance) for h in b]
        for a, b in zip(pr_u.hits, pr_s.hits)
    )
    # executor-level fragment timing: rebuild shard 0's coalesced fragment
    # and run its Stage A directly in both modes, rounds interleaved
    _meta, _snap, puffin_path, reader = c.coordinator._resolve_index("bench")
    routing = decode_routing_blob(reader.read_first(ROUTING_BLOB_TYPE))
    zonemap = c.coordinator._read_zonemap(reader, puffin_path)
    blob_by_index = dict(enumerate(reader.blobs))
    oversample = int(routing.params.get("oversample", "4"))
    preds_m = [c.coordinator._coerce_filter(f) for f in mixed_filters]
    plans_m = {
        p: planner.plan_filtered(
            p, zonemap, routing, k=10, oversample=oversample, use_pq=True
        )[0]
        for p in set(preds_m)
    }
    s0 = routing.shards[0]
    b0 = blob_by_index[s0.blob_index]
    frag = F.BatchProbeTaskInfo(
        task_id="bench-mixed-frag",
        cache_key=f"{puffin_path}#shard{s0.shard_id}",
        shard_id=s0.shard_id,
        puffin_path=puffin_path,
        blob_offset=b0.offset,
        blob_length=b0.length,
        blob_codec=b0.compression_codec,
        queries=Q,
        query_index=np.arange(len(Q), dtype=np.int64),
        k=10,
        L=int(routing.params.get("L", "100")),
        use_pq=True,
        oversample=oversample,
        filters=preds_m,
        plan_ops=[plans_m[p].get(s0.shard_id) for p in preds_m],
    )
    ex0 = c.executors[0]
    for flag in (True, False):  # warm both modes
        ex0.force_split_flavors = flag
        ex0.handle(frag)
    # paired interleaved MEDIANS: the two modes differ by a fixed
    # per-dispatch overhead (~10%) on top of shared compute, and either
    # mode occasionally eats a multi-ms allocator/GC spike (measured:
    # std 10x the mode gap) that would poison a mean and make a
    # min-of-rounds ratio a race between two noise floors — the medians
    # of the same alternating windows track the systematic gap
    split_rounds, uni_rounds = [], []
    for _ in range(15):
        ex0.force_split_flavors = True
        t0 = time.perf_counter()
        ex0.handle(frag)
        split_rounds.append(time.perf_counter() - t0)
        ex0.force_split_flavors = False
        t0 = time.perf_counter()
        ex0.handle(frag)
        uni_rounds.append(time.perf_counter() - t0)
    split_s = float(np.median(split_rounds))
    uni_s = float(np.median(uni_rounds))
    emit(
        "table2.filtered_mixed_flavor",
        mixed_s / len(Q) * 1e6,
        f"B_{len(Q)}_distinct_{len(set(mixed_filters))}"
        f"_dispatches_{pr_u.kernel_dispatches}_vs_split_{pr_s.kernel_dispatches}"
        f"_fragments_{pr_u.probe_fragments}_frag_speedup_{split_s/uni_s:.2f}x"
        f"_recall_vs_oracle_{recall_m:.3f}_parity_{'ok' if parity_m else 'BROKEN'}",
    )
    rows["table2.filtered_mixed_flavor"] = {
        "throughput_qps": len(Q) / mixed_s,
        "recall": recall_m,
        "kernel_dispatches": pr_u.kernel_dispatches,
        "split_dispatches": pr_s.kernel_dispatches,
        "probe_fragments": pr_u.probe_fragments,
        "speedup_vs_split": split_s / uni_s,
        "distinct_filters": len(set(mixed_filters)),
        "parity_ok": bool(parity_m),
    }

    # ---- low-selectivity predicate on a BIG shard: MaskedBeam vs postfilter
    # A shard above planner.EXACT_SCAN_MAX_ROWS cannot answer a filtered
    # query with a masked linear scan (the O(N·D) hole the cap exists for),
    # so below MASK_MAX_FRAC the planner routes it to MaskedBeam: a
    # predicate-aware traversal that expands through masked nodes but never
    # admits them.  The baseline is the over-fetched PostfilterBeam, whose
    # capped pool starves at low selectivity and dumps most rows into the
    # exact-masked fallback — replayed over the SAME queries via a
    # hand-authored plan, both paths timed interleaved in the same window
    # so ambient load cancels in the ratio.  check_bench gates the speedup,
    # recall vs the scan oracle, bounded dispatches (traversal rows cost no
    # masked-kernel dispatch; at most ONE fused fallback per fragment), and
    # guards the row against going vacuous: the shard must really be above
    # the cap, every row must really take the traversal, and not every
    # traversal row may fall back.
    n_big = 5_000 if tiny else 8_192
    D_big = 32
    assert n_big > planner.EXACT_SCAN_MAX_ROWS
    t_big = LakehouseTable(c.catalog, "bench_big")
    t_big.create(dim=D_big)
    Xb = clustered(rng, n_big, D_big, n_clusters=10)
    price_b = rng.integers(0, 100, size=n_big).astype(np.int64)
    t_big.append_vectors(
        Xb, num_files=4, rows_per_group=256, attributes={"price": price_b}
    )
    c.coordinator.create_index(
        "bench_big",
        IndexConfig(name="idx_big", num_shards=1, R=16 if tiny else 24,
                    L=32 if tiny else 64, partitions_per_shard=4,
                    build_passes=1, build_batch=256),
    )
    Qb = Xb[rng.choice(n_big, n_q)] + 0.05 * rng.normal(
        size=(n_q, D_big)
    ).astype(np.float32)
    flt_big = "price < 15"  # ~0.15: far below any sane over-fetch factor
    oracle_bb = c.coordinator.probe_batch(
        "bench_big", Qb, 10, strategy="scan", filter=flt_big
    )
    pr_mb = c.coordinator.probe_batch(
        "bench_big", Qb, 10, strategy="diskann", filter=flt_big
    )  # warm + capture the MaskedBeam plan
    assert "mbeam" in pr_mb.filter_plan, pr_mb.filter_plan
    post_plan = planner.ProbePlan(
        k=pr_mb.plan.k,
        oversample=pr_mb.plan.oversample,
        use_pq=pr_mb.plan.use_pq,
        ops=[
            {
                sid: (
                    planner.PostfilterBeam(
                        pool=planner.postfilter_pool(
                            10, pr_mb.plan.oversample, op.est_frac
                        ),
                        k=op.k,
                        est_frac=op.est_frac,
                    )
                    if isinstance(op, planner.MaskedBeam)
                    else op
                )
                for sid, op in row.items()
            }
            for row in pr_mb.plan.ops
        ],
        est_selectivity=pr_mb.plan.est_selectivity,
        pruned_shards=pr_mb.plan.pruned_shards,
    )
    c.coordinator.probe_batch(
        "bench_big", Qb, 10, strategy="diskann", filter=flt_big,
        replay_plan=post_plan,
    )  # warm the postfilter path (its pooled beam + fallback jit)
    mb_s = post_s = float("inf")
    pr_post = None
    for _ in range(3):
        t0 = time.perf_counter()
        pr_post = c.coordinator.probe_batch(
            "bench_big", Qb, 10, strategy="diskann", filter=flt_big,
            replay_plan=post_plan,
        )
        post_s = min(post_s, time.perf_counter() - t0)
        t0 = time.perf_counter()
        pr_mb = c.coordinator.probe_batch(
            "bench_big", Qb, 10, strategy="diskann", filter=flt_big
        )
        mb_s = min(mb_s, time.perf_counter() - t0)
    truth_bb = [
        {(h.file_path, h.row_group, h.row_offset) for h in hits}
        for hits in oracle_bb.hits
    ]
    recall_bb = float(np.mean([
        len({(h.file_path, h.row_group, h.row_offset) for h in hits} & tb)
        / max(len(tb), 1)
        for hits, tb in zip(pr_mb.hits, truth_bb)
    ]))
    emit(
        "table2.filtered_lowsel_bigshard",
        mb_s / len(Qb) * 1e6,
        f"B_{len(Qb)}_rows_{n_big}_sel_{pr_mb.est_selectivity:.3f}"
        f"_mbeam_rows_{pr_mb.masked_beam_rows}"
        f"_fallbacks_{pr_mb.masked_beam_fallbacks}"
        f"_dispatches_{pr_mb.kernel_dispatches}"
        f"_speedup_vs_postfilter_{post_s/mb_s:.2f}x"
        f"_recall_vs_oracle_{recall_bb:.3f}",
    )
    rows["table2.filtered_lowsel_bigshard"] = {
        "throughput_qps": len(Qb) / mb_s,
        "postfilter_qps": len(Qb) / post_s,
        "speedup_vs_postfilter": post_s / mb_s,
        "recall": recall_bb,
        "est_selectivity": pr_mb.est_selectivity,
        "shard_rows": n_big,
        "exact_scan_cap": planner.EXACT_SCAN_MAX_ROWS,
        "batch_queries": len(Qb),
        "masked_beam_rows": pr_mb.masked_beam_rows,
        "masked_beam_fallbacks": pr_mb.masked_beam_fallbacks,
        "postfilter_dispatches": pr_post.kernel_dispatches,
        "kernel_dispatches": pr_mb.kernel_dispatches,
        "probe_fragments": pr_mb.probe_fragments,
        "plan_mbeam": "mbeam" in pr_mb.filter_plan,
    }

    # ---- freshness: append → probe with NO refresh (fresh-tail tier) ------
    # Sustained write load: append a tail (~1/16 of the corpus), then probe
    # immediately against the now-stale index binding.  The scan oracle
    # reads the snapshot's own file list, so it is fresh by construction;
    # the tail tier must hold recall vs it >= 0.95 with unindexed_rows == 0
    # (the silent stale-read window this tier closes), carrying exactly one
    # plan op per unindexed row group.  ``recall_without_tail`` records the
    # pre-fix silent-drop recall for the staleness axis; latency is the
    # stale-probe p50 (tail scan riding the same wave as the graph shards).
    n_tail = max(len(X) // 16, rows_per_group)
    Xt = clustered(rng, n_tail, D, n_clusters=8)
    t.append_vectors(
        Xt,
        num_files=1,
        rows_per_group=rows_per_group,
        file_prefix="tail",
        attributes={
            "category": np.asarray(["tail"] * n_tail),
            "price": rng.integers(0, 100, size=n_tail).astype(np.int64),
        },
    )
    # half the queries target old (indexed) rows, half the fresh tail
    half = len(Q) // 2
    Qf = np.concatenate([
        Q[:half],
        Xt[rng.choice(n_tail, len(Q) - half)]
        + 0.05 * rng.normal(size=(len(Q) - half, D)).astype(np.float32),
    ])
    c.coordinator.probe_batch("bench", Qf, 10, strategy="diskann")  # warm
    oracle_fs = stale_s = float("inf")
    oracle_fr = pr_t = None
    for _ in range(3):
        t0 = time.perf_counter()
        oracle_fr = c.coordinator.probe_batch("bench", Qf, 10, strategy="scan")
        oracle_fs = min(oracle_fs, time.perf_counter() - t0)
        t0 = time.perf_counter()
        pr_t = c.coordinator.probe_batch("bench", Qf, 10, strategy="diskann")
        stale_s = min(stale_s, time.perf_counter() - t0)
    truth_t = [
        {(h.file_path, h.row_group, h.row_offset) for h in hits}
        for hits in oracle_fr.hits
    ]
    def _recall_vs_fresh(rep):
        return float(np.mean([
            len({(h.file_path, h.row_group, h.row_offset) for h in hits} & tt)
            / max(len(tt), 1)
            for hits, tt in zip(rep.hits, truth_t)
        ]))
    recall_t = _recall_vs_fresh(pr_t)
    # the pre-fix behavior, for the staleness axis: tail tier off
    pr_drop = c.coordinator.probe_batch(
        "bench", Qf, 10, strategy="diskann", include_tail=False
    )
    recall_drop = _recall_vs_fresh(pr_drop)
    tail_rgs = -(n_tail // -rows_per_group)  # ceil: row groups in the tail
    tail_plan_ops = (
        len([sid for sid in pr_t.plan.ops[0] if sid < 0]) if pr_t.plan else 0
    )
    emit(
        "table2.freshness",
        stale_s / len(Qf) * 1e6,
        f"B_{len(Qf)}_tail_rows_{pr_t.tail_rows}_rgs_{tail_rgs}"
        f"_recall_vs_oracle_{recall_t:.3f}_without_tail_{recall_drop:.3f}"
        f"_unindexed_{pr_t.unindexed_rows}_stale_{pr_t.stale}"
        f"_p50_ms_{stale_s/len(Qf)*1e3:.1f}",
    )
    rows["table2.freshness"] = {
        "throughput_qps": len(Qf) / stale_s,
        "recall": recall_t,
        "recall_without_tail": recall_drop,
        "tail_rows": pr_t.tail_rows,
        "tail_row_groups": tail_rgs,
        "tail_plan_ops": tail_plan_ops,
        "unindexed_rows": pr_t.unindexed_rows,
        "stale": bool(pr_t.stale),
        "oracle_qps": len(Qf) / oracle_fs,
    }

    # ---- overload: two tenants at ~2x capacity, one abusive ------------
    # The serving tier's admission-control contract: with offered load about
    # twice what the cluster can serve, an ABUSIVE tenant (flooding far past
    # its token-bucket rate) must absorb the rejections while the
    # well-behaved tenant keeps a >= 0.9 deadline hit-rate and the bounded
    # submission queue never grows past its cap.
    import queue as queue_mod
    import threading

    from repro.serving.admission import AdmissionRejected, TenantPolicy
    from repro.serving.serve_loop import ProbeMicroBatcher

    batch_s, _ = _best_of(
        lambda: c.coordinator.probe_batch("bench", Q, 10, strategy="diskann")
    )
    capacity_qps = n_q / batch_s  # warm micro-batch service rate
    well_qps = 0.25 * capacity_qps
    abusive_qps = 1.75 * capacity_qps  # offered, mostly refused at the door
    duration_s = 2.0
    max_queue = 64
    deadline_ms = max(1000.0, 20.0 * batch_s * 1e3)
    counts = {
        "well_attempts": 0, "well_full": 0,
        "abusive_attempts": 0, "abusive_admitted": 0, "abusive_rejected": 0,
    }
    well_futs: list = []
    peak_q = [0]
    with ProbeMicroBatcher(
        c.coordinator,
        "bench",
        strategy="diskann",
        max_batch=max(8, n_q),
        max_wait_s=0.002,
        max_queue=max_queue,
        tenant_policies={
            # the abuser's budget: ~25% of capacity, everything past it
            # bounces off its own bucket instead of the shared queue
            "abusive": TenantPolicy(rate_qps=0.25 * capacity_qps, burst=8.0),
        },
    ) as mb:
        stop_at = time.perf_counter() + duration_s

        def flood():
            # absolute schedule: sleep-to-next-tick, so the OFFERED rate
            # holds even when sleep() overshoots at millisecond intervals
            next_t = time.perf_counter()
            while time.perf_counter() < stop_at:
                counts["abusive_attempts"] += 1
                try:
                    mb.submit(
                        Q[counts["abusive_attempts"] % n_q], 10,
                        tenant="abusive", deadline_ms=deadline_ms,
                    )
                    counts["abusive_admitted"] += 1
                except (AdmissionRejected, queue_mod.Full):
                    counts["abusive_rejected"] += 1
                next_t += 1.0 / abusive_qps
                delay = next_t - time.perf_counter()
                if delay > 0:
                    time.sleep(delay)

        flooder = threading.Thread(target=flood)
        flooder.start()
        next_t = time.perf_counter()
        while time.perf_counter() < stop_at:
            counts["well_attempts"] += 1
            try:
                well_futs.append(mb.submit(
                    Q[counts["well_attempts"] % n_q], 10,
                    tenant="well", deadline_ms=deadline_ms,
                ))
            except (AdmissionRejected, queue_mod.Full):
                counts["well_full"] += 1
            peak_q[0] = max(peak_q[0], mb._queue.qsize())
            next_t += 1.0 / well_qps
            delay = next_t - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
        flooder.join()
        well_served = 0
        for f in well_futs:
            try:
                f.result(timeout=30)
                well_served += 1
            except Exception:
                pass
        served_total = mb.stats.queries
        deadline_misses = mb.stats.deadline_misses
        degraded_batches = mb.stats.degraded_batches
    offered_qps = (counts["well_attempts"] + counts["abusive_attempts"]) / duration_s
    well_hit_rate = well_served / max(1, counts["well_attempts"])
    well_rejected = counts["well_full"] + (len(well_futs) - well_served)
    queue_bounded = peak_q[0] <= max_queue
    emit(
        "table2.overload",
        duration_s / max(1, served_total) * 1e6,
        f"capacity_{capacity_qps:.0f}qps_offered_{offered_qps:.0f}qps"
        f"_x{offered_qps / capacity_qps:.1f}_well_hit_{well_hit_rate:.2f}"
        f"_abusive_rej_{counts['abusive_rejected']}"
        f"_deadline_misses_{deadline_misses}_peak_queue_{peak_q[0]}",
    )
    rows["table2.overload"] = {
        "throughput_qps": served_total / duration_s,
        "capacity_qps": capacity_qps,
        "offered_qps": offered_qps,
        "overload_factor": offered_qps / capacity_qps,
        "well_hit_rate": well_hit_rate,
        "well_attempts": counts["well_attempts"],
        "well_served": well_served,
        "well_rejected": well_rejected,
        "abusive_attempts": counts["abusive_attempts"],
        "abusive_admitted": counts["abusive_admitted"],
        "abusive_rejected": counts["abusive_rejected"],
        "deadline_misses": deadline_misses,
        "degraded_batches": degraded_batches,
        "queue_bounded": queue_bounded,
    }

    # ---- zipfian: the serving-tier cache hierarchy under skewed traffic --
    # Real streams are Zipfian: a Zipf(s≈1.1) stream over a fixed query
    # pool, two tenants, warm (both cache layers on) vs cold (caches off)
    # interleaved per event in the SAME timing window.  Repeats within a
    # tenant hit the semantic result cache at the batcher's door; the first
    # cross-tenant repeat misses the (per-tenant) semantic layer and hits
    # the shared shard-probe cache instead.  The row also proves the two
    # correctness claims the gate enforces: bit-parity with the cache-off
    # path on non-repeating AND fully-cached traffic, and a refresh commit
    # invalidating both layers with zero stale answers afterwards.
    from repro.serving.cache import SemanticResultCache, ShardProbeCache

    pool_n = 16
    pool = (
        X[rng.choice(len(X), pool_n)]
        + 0.05 * rng.normal(size=(pool_n, D)).astype(np.float32)
    ).astype(np.float32)
    oracle_zr = c.coordinator.probe_batch("bench", pool, 10, strategy="scan")
    truth_z = [
        {(h.file_path, h.row_group, h.row_offset) for h in hits}
        for hits in oracle_zr.hits
    ]
    zipf_s = 1.1
    zr = np.arange(1, pool_n + 1, dtype=np.float64)
    pz = zr ** -zipf_s
    pz /= pz.sum()
    stream_len = 96 if tiny else 128
    stream = rng.choice(pool_n, size=stream_len, p=pz)
    tenant_stream = np.where(rng.random(stream_len) < 0.5, "tenant_a", "tenant_b")

    def _locs_z(rep):
        return [
            [(h.file_path, h.row_group, h.row_offset) for h in hs]
            for hs in rep.hits
        ]

    shard_cache = ShardProbeCache(max_bytes=8 << 20)
    sem_cache = SemanticResultCache(max_bytes=4 << 20, distance_threshold=1e-4)
    warm_lat: list = []
    cold_lat: list = []
    warm_answers: list = []
    mb_warm = ProbeMicroBatcher(
        c.coordinator, "bench", strategy="diskann", max_wait_s=0.0005,
        semantic_cache=sem_cache,
    ).start()
    mb_cold = ProbeMicroBatcher(
        c.coordinator, "bench", strategy="diskann", max_wait_s=0.0005
    ).start()
    try:
        for pi, ten in zip(stream, tenant_stream):
            q = pool[pi]
            # cold leg first, caches off — same interleaved window
            c.coordinator.probe_cache = None
            t0 = time.perf_counter()
            mb_cold.submit(q, 10, tenant=str(ten)).result(timeout=60)
            cold_lat.append(time.perf_counter() - t0)
            # warm leg, both layers on
            c.coordinator.probe_cache = shard_cache
            t0 = time.perf_counter()
            wh = mb_warm.submit(q, 10, tenant=str(ten)).result(timeout=60)
            warm_lat.append(time.perf_counter() - t0)
            warm_answers.append((int(pi), wh))
        sem_hits = mb_warm.stats.semantic_hits
        sem_misses = mb_warm.stats.semantic_misses
        shard_hits = shard_cache.stats.hits
        shard_lookups = shard_cache.stats.hits + shard_cache.stats.misses
        recall_z = float(np.mean([
            len({(h.file_path, h.row_group, h.row_offset) for h in hs}
                & truth_z[pi]) / max(len(truth_z[pi]), 1)
            for pi, hs in warm_answers
        ]))
        # bit-parity proof: a FRESH shard cache on non-repeating traffic
        # (first pass populates, zero hits) and on a full repeat (every
        # fragment a hit) both match the cache-off path exactly
        parity_cache = ShardProbeCache(max_bytes=8 << 20)
        c.coordinator.probe_cache = None
        off_rep = c.coordinator.probe_batch("bench", pool, 10, strategy="diskann")
        c.coordinator.probe_cache = parity_cache
        on_first = c.coordinator.probe_batch("bench", pool, 10, strategy="diskann")
        on_replay = c.coordinator.probe_batch("bench", pool, 10, strategy="diskann")
        parity_ok = bool(
            _locs_z(off_rep) == _locs_z(on_first) == _locs_z(on_replay)
        )
        replay_cache_hits = int(on_replay.shard_cache_hits)
        # refresh: the snapshot commit is the invalidation token for BOTH
        # layers; afterwards, caches-on must equal caches-off exactly
        n_zt = rows_per_group
        t.append_vectors(
            clustered(rng, n_zt, D, n_clusters=4),
            num_files=1,
            rows_per_group=rows_per_group,
            attributes={
                "category": np.asarray(["zfresh"] * n_zt),
                "price": rng.integers(0, 100, size=n_zt).astype(np.int64),
            },
        )
        c.coordinator.probe_cache = shard_cache
        c.coordinator.refresh_index("bench", "idx")
        invalidations = int(
            shard_cache.stats.invalidations + sem_cache.stats.invalidations
        )
        post_on = c.coordinator.probe_batch("bench", pool, 10, strategy="diskann")
        c.coordinator.probe_cache = None
        post_off = c.coordinator.probe_batch("bench", pool, 10, strategy="diskann")
        stale_hits = sum(
            1 for a, b in zip(_locs_z(post_on), _locs_z(post_off)) if a != b
        )
        # the semantic layer must re-probe too (entries evicted at commit)
        wh_post = mb_warm.submit(
            pool[0], 10, tenant="tenant_a"
        ).result(timeout=60)
        stale_hits += int(mb_warm.stats.semantic_hits > sem_hits)
        stale_hits += int(
            [(h.file_path, h.row_group, h.row_offset) for h in wh_post]
            != _locs_z(post_off)[0]
        )
    finally:
        mb_warm.stop()
        mb_cold.stop()
        c.coordinator.probe_cache = None
    warm_p50, warm_p99 = np.percentile(np.array(warm_lat) * 1e3, [50, 99])
    cold_p50, cold_p99 = np.percentile(np.array(cold_lat) * 1e3, [50, 99])
    emit(
        "table2.zipfian",
        float(np.sum(warm_lat)) / stream_len * 1e6,
        f"pool_{pool_n}_stream_{stream_len}_sem_hits_{sem_hits}"
        f"_shard_hits_{shard_hits}_warm_p50_ms_{warm_p50:.2f}"
        f"_cold_p50_ms_{cold_p50:.2f}_recall_{recall_z:.3f}"
        f"_inval_{invalidations}_stale_{stale_hits}_parity_{parity_ok}",
    )
    rows["table2.zipfian"] = {
        "throughput_qps": stream_len / float(np.sum(warm_lat)),
        "recall": recall_z,
        "zipf_s": zipf_s,
        "pool_size": pool_n,
        "stream_len": stream_len,
        "semantic_hits": int(sem_hits),
        "semantic_misses": int(sem_misses),
        "semantic_hit_rate": sem_hits / stream_len,
        "shard_hits": int(shard_hits),
        "shard_lookups": int(shard_lookups),
        "shard_hit_rate": shard_hits / max(1, shard_lookups),
        "warm_p50_ms": float(warm_p50),
        "warm_p99_ms": float(warm_p99),
        "cold_p50_ms": float(cold_p50),
        "cold_p99_ms": float(cold_p99),
        "parity_ok": parity_ok,
        "replay_cache_hits": replay_cache_hits,
        "invalidations": invalidations,
        "stale_hits": int(stale_hits),
    }

    if json_path:
        doc = {
            "meta": {"bench": "bench_query_paths", "tiny": tiny, "n_vec": n_vec,
                     "n_queries": n_q, "dim": D},
            "rows": rows,
        }
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale smoke run (CI)")
    ap.add_argument("--json", dest="json_path", default="BENCH_query_paths.json",
                    help="machine-readable output for scripts/check_bench.py "
                         "('' disables)")
    main(**vars(ap.parse_args()))
