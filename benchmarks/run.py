"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage::

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run kernels    # one suite
"""

import sys
import time
import traceback

SUITES = ["kernels", "index_sizes", "build", "query_paths", "refresh", "recall"]


def main() -> None:
    wanted = sys.argv[1:] or SUITES
    print("name,us_per_call,derived")
    failures = 0
    for name in wanted:
        mod_name = f"benchmarks.bench_{name}"
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"suite.{name},{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            print(f"suite.{name},{(time.time()-t0)*1e6:.0f},FAILED_{type(e).__name__}")
            failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
