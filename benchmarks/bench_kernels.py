"""Kernel microbenchmarks: ref-backend wall time + Pallas(interpret) parity.

Wall-clock here is CPU (the TPU numbers are the roofline analysis in
EXPERIMENTS.md); the derived field reports achieved GFLOP/s on CPU plus a
correctness delta vs the oracle.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops


def _bench(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def main() -> None:
    rng = np.random.default_rng(0)
    # exact rerank: 256 queries × 8192 candidates × 768 d
    Q = jnp.asarray(rng.normal(size=(256, 768)).astype(np.float32))
    X = jnp.asarray(rng.normal(size=(8192, 768)).astype(np.float32))
    s, out = _bench(lambda a, b: ops.exact_distances(a, b, backend="ref"), Q, X)
    flops = 2 * 256 * 8192 * 768
    small = ops.exact_distances(Q[:8], X[:64], backend="pallas")
    ref_small = ops.exact_distances(Q[:8], X[:64], backend="ref")
    delta = float(jnp.abs(small - ref_small).max())
    emit("kernel.rerank", s * 1e6, f"gflops_{flops/s/1e9:.1f}_pallas_delta_{delta:.2e}")

    # PQ ADC scan: 16 queries × 65536 codes, m=48 K=256
    luts = jnp.asarray(rng.normal(size=(16, 48, 256)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 256, size=(65536, 48)).astype(np.int32))
    s, _ = _bench(lambda a, b: ops.pq_scan(a, b, backend="ref"), luts, codes)
    lut_ops = 16 * 65536 * 48
    small_p = ops.pq_scan(luts[:2], codes[:256], backend="pallas", tile_q=2, tile_n=128)
    small_r = ops.pq_scan(luts[:2], codes[:256], backend="ref")
    delta = float(jnp.abs(small_p - small_r).max())
    emit("kernel.pq_scan", s * 1e6, f"glookups_{lut_ops/s/1e9:.2f}_pallas_delta_{delta:.2e}")

    # masked exact top-k: 64 queries × 32768 points × 96 d, ~30% selectivity
    # (the filtered-probe Stage-A kernel: mask fused before the in-kernel
    # per-tile top-k — no pool widening, no post-hoc filter)
    Qm = jnp.asarray(rng.normal(size=(64, 96)).astype(np.float32))
    Xm = jnp.asarray(rng.normal(size=(32768, 96)).astype(np.float32))
    mask = jnp.asarray(rng.random(32768) < 0.3)
    s, _ = _bench(lambda a, b, m: ops.masked_exact_topk(a, b, m, 40, backend="ref"), Qm, Xm, mask)
    flops = 2 * 64 * 32768 * 96
    dp, _ = ops.masked_exact_topk(Qm[:8], Xm[:256], mask[:256], 10, backend="pallas")
    dr, _ = ops.masked_exact_topk(Qm[:8], Xm[:256], mask[:256], 10, backend="ref")
    dp, dr = np.asarray(dp), np.asarray(dr)
    delta = float(np.abs(np.where(np.isinf(dp), 0, dp) - np.where(np.isinf(dr), 0, dr)).max())
    emit("kernel.masked_exact_topk", s * 1e6, f"gflops_{flops/s/1e9:.1f}_pallas_delta_{delta:.2e}")

    # masked PQ-ADC top-k: 16 queries × 65536 codes, m=48 K=256, ~30% pass
    maskc = jnp.asarray(rng.random(65536) < 0.3)
    s, _ = _bench(lambda a, b, m: ops.masked_pq_topk(a, b, m, 40, backend="ref"), luts, codes, maskc)
    lut_ops = 16 * 65536 * 48
    dp, _ = ops.masked_pq_topk(luts[:2], codes[:256], maskc[:256], 10, backend="pallas", tile_q=2)
    dr, _ = ops.masked_pq_topk(luts[:2], codes[:256], maskc[:256], 10, backend="ref")
    dp, dr = np.asarray(dp), np.asarray(dr)
    delta = float(np.abs(np.where(np.isinf(dp), 0, dp) - np.where(np.isinf(dr), 0, dr)).max())
    emit("kernel.masked_pq_topk", s * 1e6, f"glookups_{lut_ops/s/1e9:.2f}_pallas_delta_{delta:.2e}")

    # k-means assign: 65536 points × 1024 centroids × 96 d
    P = jnp.asarray(rng.normal(size=(65536, 96)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(1024, 96)).astype(np.float32))
    s, _ = _bench(lambda a, b: ops.kmeans_assign(a, b, backend="ref"), P, C)
    flops = 2 * 65536 * 1024 * 96
    ip, dp = ops.kmeans_assign(P[:512], C[:128], backend="pallas", tile_n=128, tile_k=64)
    ir, dr = ops.kmeans_assign(P[:512], C[:128], backend="ref")
    agree = float(np.mean(np.asarray(ip) == np.asarray(ir)))
    emit("kernel.kmeans_assign", s * 1e6, f"gflops_{flops/s/1e9:.1f}_pallas_agree_{agree:.3f}")


if __name__ == "__main__":
    main()
