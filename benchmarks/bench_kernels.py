"""Kernel microbenchmarks: ref-backend wall time + Pallas(interpret) parity.

Wall-clock here is CPU (the TPU numbers are the roofline analysis in
EXPERIMENTS.md); the derived field reports achieved GFLOP/s on CPU plus a
correctness delta vs the oracle.

Every row is also written to ``--json`` (default ``BENCH_kernels.json``)
as ``{"rows": {name: {"throughput_qps": ..., ...}}}`` — the second bench
record scripts/check_bench.py gates CI on.  Kernel rows are
throughput-gated against the committed baseline in benchmarks/baselines/
with the median-ratio machine-factor normalization, so the measurement
must be noise-robust: timing is best-of-N with the rounds INTERLEAVED
across all kernels (round-robin), not N back-to-back calls per kernel.
A load spike on a shared runner then hits every kernel's same rounds
instead of unluckily sinking one row — either every row's min comes from
a clean round, or every row is uniformly slow and the machine factor
divides the slowdown out.  (Measured: per-kernel best-of swings up to 4x
between runs on a busy container; interleaved best-of holds the
cross-row RATIOS steady, which is all the gate needs.)
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref
from repro.runtime import planner

TIMING_ROUNDS = 16


def _recall(got_ids: np.ndarray, want_ids: np.ndarray) -> float:
    """Mean per-row top-k id overlap (sentinel slots excluded)."""
    hits = total = 0
    for qi in range(want_ids.shape[0]):
        want = set(int(v) for v in want_ids[qi] if v >= 0)
        got = set(int(v) for v in got_ids[qi] if v >= 0)
        hits += len(want & got)
        total += len(want)
    return hits / max(total, 1)


def _masked_delta(dp, dr):
    dp, dr = np.asarray(dp), np.asarray(dr)
    return float(
        np.abs(np.where(np.isinf(dp), 0, dp) - np.where(np.isinf(dr), 0, dr)).max()
    )


def main(json_path: str = "BENCH_kernels.json") -> None:
    rng = np.random.default_rng(0)

    # ---- inputs ----------------------------------------------------------
    # exact rerank: 128 queries × 4096 candidates × 768 d
    Q = jnp.asarray(rng.normal(size=(128, 768)).astype(np.float32))
    X = jnp.asarray(rng.normal(size=(4096, 768)).astype(np.float32))
    # PQ ADC scan: 8 queries × 32768 codes, m=48 K=256
    luts = jnp.asarray(rng.normal(size=(8, 48, 256)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 256, size=(32768, 48)).astype(np.int32))
    # masked exact top-k: 32 queries × 16384 points × 96 d, ~30% selectivity
    # (the filtered-probe Stage-A kernel: mask fused before the in-kernel
    # per-tile top-k — no pool widening, no post-hoc filter)
    Qm = jnp.asarray(rng.normal(size=(32, 96)).astype(np.float32))
    Xm = jnp.asarray(rng.normal(size=(16384, 96)).astype(np.float32))
    mask = jnp.asarray(rng.random(16384) < 0.3)
    maskc = jnp.asarray(rng.random(32768) < 0.3)
    # multi-mask variants: same loads but EACH query carries its own (N,)
    # bitmask — the heterogeneous-filter plane path: one call instead of
    # one per predicate group
    planes = jnp.asarray(rng.random((32, 16384)) < 0.3)
    planes_c = jnp.asarray(rng.random((8, 32768)) < 0.3)
    # unified exact/PQ kernel: the masked-exact load PLUS per-row ADC
    # inputs and an alternating flavor vector — the mixed-flavor fragment's
    # single dispatch (replaces one exact + one ADC call)
    luts_u = jnp.asarray(rng.normal(size=(32, 12, 256)).astype(np.float32))
    codes_u = jnp.asarray(rng.integers(0, 256, size=(16384, 12)).astype(np.int32))
    flavor_u = jnp.asarray((np.arange(32) % 2).astype(bool))
    # gather-rerank: 64 queries × 256-candidate pools over the rerank
    # corpus — the Stage-B pool rerank that used to be a NumPy
    # (Q, P, D) gather + einsum on the host.  The host comparator below is
    # that removed code, timed in the same interleaved window so the
    # speedup_vs_host ratio is load-cancelling.
    Qg = Q[:64]
    pool_ids = jnp.asarray(rng.integers(0, 4096, size=(64, 256)).astype(np.int32))
    Qg_h, X_h, pool_h = np.asarray(Qg), np.asarray(X), np.asarray(pool_ids)

    def host_pool_rerank():
        safe = np.clip(pool_h, 0, X_h.shape[0] - 1)
        vecs = X_h[safe]  # (Q, P, D) — the allocation the kernel avoids
        d = np.sum((vecs - Qg_h[:, None, :]) ** 2, axis=-1)
        d = np.where(pool_h < 0, np.inf, d)
        order = np.argsort(d, axis=1)[:, :40]
        return (
            np.take_along_axis(d, order, axis=1),
            np.take_along_axis(pool_h, order, axis=1),
        )

    # quantized exact-scan flavors: the masked-exact load scored from the
    # cached pre-quantized stored matrix (exactly what the executor ships);
    # on non-TPU backends the honest scoring path dequantizes to f32, so
    # the row records quantized_native=False and check_bench applies the
    # non-native floor instead of the >1x speedup gate.
    quantized_native = jax.devices()[0].platform == "tpu"
    stored_bf, sc_bf = ref.quantize_points(Xm, "bf16")
    stored_i8, sc_i8 = ref.quantize_points(Xm, "int8")
    # k-means assign: 16384 points × 512 centroids × 96 d
    P = jnp.asarray(rng.normal(size=(16384, 96)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(512, 96)).astype(np.float32))
    # machine-speed anchor: a fixed PURE-NUMPY matmul no repo change can
    # touch.  check_bench derives the machine factor from anchor.* rows
    # when present, so a uniform slowdown of every kernel.* row (a real
    # regression in a shared helper) is no longer indistinguishable from
    # a slower runner — the anchor pins what "machine speed" means.
    A_anchor = rng.normal(size=(512, 512)).astype(np.float32)
    B_anchor = rng.normal(size=(512, 512)).astype(np.float32)

    # ---- timed thunks (ref backend — the production CPU path) ------------
    cases = {
        "kernel.rerank": lambda: ops.exact_distances(Q, X, backend="ref"),
        "kernel.pq_scan": lambda: ops.pq_scan(luts, codes, backend="ref"),
        "kernel.masked_exact_topk": lambda: ops.masked_exact_topk(
            Qm, Xm, mask, 40, backend="ref"
        ),
        "kernel.masked_pq_topk": lambda: ops.masked_pq_topk(
            luts, codes, maskc, 40, backend="ref"
        ),
        "kernel.masked_exact_topk_multi": lambda: ops.masked_exact_topk_multi(
            Qm, Xm, planes, 40, backend="ref"
        ),
        "kernel.masked_pq_topk_multi": lambda: ops.masked_pq_topk_multi(
            luts, codes, planes_c, 40, backend="ref"
        ),
        "kernel.unified_masked_topk": lambda: ops.unified_masked_topk(
            Qm, Xm, luts_u, codes_u, planes, flavor_u, 40, backend="ref"
        ),
        "kernel.masked_exact_topk_bf16": lambda: ops.masked_exact_topk(
            Qm, stored_bf, mask, 40, backend="ref", dtype="bf16", x_scale=sc_bf
        ),
        "kernel.masked_exact_topk_int8": lambda: ops.masked_exact_topk(
            Qm, stored_i8, mask, 40, backend="ref", dtype="int8", x_scale=sc_i8
        ),
        "kernel.gather_rerank": lambda: ops.gather_rerank(
            Qg, X, pool_ids, 40, backend="ref"
        ),
        "host.gather_rerank": host_pool_rerank,
        "kernel.kmeans_assign": lambda: ops.kmeans_assign(P, C, backend="ref"),
        "anchor.numpy_matmul": lambda: A_anchor @ B_anchor,
    }
    best = {name: float("inf") for name in cases}
    for name, fn in cases.items():  # warm (traces, allocator)
        jax.block_until_ready(fn())
    for _ in range(TIMING_ROUNDS):  # interleaved rounds (see module doc)
        for name, fn in cases.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[name] = min(best[name], time.perf_counter() - t0)

    # ---- Pallas(interpret) parity on small slices ------------------------
    delta = {}
    small = ops.exact_distances(Q[:8], X[:64], backend="pallas")
    ref_small = ops.exact_distances(Q[:8], X[:64], backend="ref")
    delta["kernel.rerank"] = float(jnp.abs(small - ref_small).max())
    small_p = ops.pq_scan(luts[:2], codes[:256], backend="pallas", tile_q=2, tile_n=128)
    small_r = ops.pq_scan(luts[:2], codes[:256], backend="ref")
    delta["kernel.pq_scan"] = float(jnp.abs(small_p - small_r).max())
    delta["kernel.masked_exact_topk"] = _masked_delta(
        ops.masked_exact_topk(Qm[:8], Xm[:256], mask[:256], 10, backend="pallas")[0],
        ops.masked_exact_topk(Qm[:8], Xm[:256], mask[:256], 10, backend="ref")[0],
    )
    delta["kernel.masked_pq_topk"] = _masked_delta(
        ops.masked_pq_topk(luts[:2], codes[:256], maskc[:256], 10, backend="pallas", tile_q=2)[0],
        ops.masked_pq_topk(luts[:2], codes[:256], maskc[:256], 10, backend="ref")[0],
    )
    small_pl = jnp.asarray(np.asarray(planes)[:8, :256])
    delta["kernel.masked_exact_topk_multi"] = _masked_delta(
        ops.masked_exact_topk_multi(Qm[:8], Xm[:256], small_pl, 10, backend="pallas")[0],
        ops.masked_exact_topk_multi(Qm[:8], Xm[:256], small_pl, 10, backend="ref")[0],
    )
    small_pc = jnp.asarray(np.asarray(planes_c)[:2, :256])
    delta["kernel.masked_pq_topk_multi"] = _masked_delta(
        ops.masked_pq_topk_multi(luts[:2], codes[:256], small_pc, 10, backend="pallas", tile_q=2)[0],
        ops.masked_pq_topk_multi(luts[:2], codes[:256], small_pc, 10, backend="ref")[0],
    )
    delta["kernel.unified_masked_topk"] = _masked_delta(
        ops.unified_masked_topk(
            Qm[:8], Xm[:256], luts_u[:8], codes_u[:256], small_pl, flavor_u[:8],
            10, backend="pallas",
        )[0],
        ops.unified_masked_topk(
            Qm[:8], Xm[:256], luts_u[:8], codes_u[:256], small_pl, flavor_u[:8],
            10, backend="ref",
        )[0],
    )
    delta["kernel.gather_rerank"] = _masked_delta(
        ops.gather_rerank(Qg[:8], X[:256], pool_ids[:8, :32], 10, backend="pallas")[0],
        ops.gather_rerank(Qg[:8], X[:256], pool_ids[:8, :32], 10, backend="ref")[0],
    )
    delta["kernel.masked_exact_topk_bf16"] = _masked_delta(
        ops.masked_exact_topk(
            Qm[:8], Xm[:256], mask[:256], 10, backend="pallas", dtype="bf16"
        )[0],
        ops.masked_exact_topk(
            Qm[:8], Xm[:256], mask[:256], 10, backend="ref", dtype="bf16"
        )[0],
    )
    delta["kernel.masked_exact_topk_int8"] = _masked_delta(
        ops.masked_exact_topk(
            Qm[:8], Xm[:256], mask[:256], 10, backend="pallas", dtype="int8"
        )[0],
        ops.masked_exact_topk(
            Qm[:8], Xm[:256], mask[:256], 10, backend="ref", dtype="int8"
        )[0],
    )
    ip, _ = ops.kmeans_assign(P[:512], C[:128], backend="pallas", tile_n=128, tile_k=64)
    ir, _ = ops.kmeans_assign(P[:512], C[:128], backend="ref")
    agree = float(np.mean(np.asarray(ip) == np.asarray(ir)))

    # ---- quantized recall + unified parity (auto backend, full inputs) ---
    _fd, f32_ids = ops.masked_exact_topk(Qm, Xm, mask, 40, backend="auto")
    f32_ids = np.asarray(f32_ids)
    quant_extras = {}
    guard_pool = min(planner.quant_guard_pool(40), int(Xm.shape[0]))
    for row_name, stored, scale in (
        ("kernel.masked_exact_topk_bf16", stored_bf, sc_bf),
        ("kernel.masked_exact_topk_int8", stored_i8, sc_i8),
    ):
        dt = row_name.rsplit("_", 1)[1]
        _qd, raw_ids = ops.masked_exact_topk(
            Qm, stored, mask, 40, backend="auto", dtype=dt, x_scale=scale
        )
        _pd, pool_pids = ops.masked_exact_topk(
            Qm, stored, mask, guard_pool, backend="auto", dtype=dt, x_scale=scale
        )
        _gd, guard_ids = ops.gather_rerank(Qm, Xm, pool_pids, 40, backend="auto")
        quant_extras[row_name] = {
            "recall_raw": _recall(np.asarray(raw_ids), f32_ids),
            "recall_post_guard": _recall(np.asarray(guard_ids), f32_ids),
            "quantized_native": quantized_native,
        }
    # unified parity: the fused dispatch answers exactly what the two
    # split-flavor dispatches answer, row for row, on the full bench load
    du, iu = ops.unified_masked_topk(
        Qm, Xm, luts_u, codes_u, planes, flavor_u, 40, backend="auto"
    )
    de, ie = ops.masked_exact_topk_multi(Qm, Xm, planes, 40, backend="auto")
    da, ia = ops.masked_pq_topk_multi(luts_u, codes_u, planes, 40, backend="auto")
    iu, ie, ia = np.asarray(iu), np.asarray(ie), np.asarray(ia)
    flav = np.asarray(flavor_u)
    unified_parity = all(
        np.array_equal(iu[qi], (ia if flav[qi] else ie)[qi]) for qi in range(iu.shape[0])
    )

    # ---- report ----------------------------------------------------------
    work = {  # per-call work for the derived column
        "kernel.rerank": ("gflops", 2 * 128 * 4096 * 768),
        "kernel.pq_scan": ("glookups", 8 * 32768 * 48),
        "kernel.masked_exact_topk": ("gflops", 2 * 32 * 16384 * 96),
        "kernel.masked_pq_topk": ("glookups", 8 * 32768 * 48),
        "kernel.masked_exact_topk_multi": ("gflops", 2 * 32 * 16384 * 96),
        "kernel.masked_pq_topk_multi": ("glookups", 8 * 32768 * 48),
        # one pass computes both score planes: exact flops + ADC lookups
        "kernel.unified_masked_topk": ("gflops", 2 * 32 * 16384 * 96),
        "kernel.masked_exact_topk_bf16": ("gflops", 2 * 32 * 16384 * 96),
        "kernel.masked_exact_topk_int8": ("gflops", 2 * 32 * 16384 * 96),
        "kernel.gather_rerank": ("gflops", 2 * 64 * 256 * 768),
        "host.gather_rerank": ("gflops", 2 * 64 * 256 * 768),
        "kernel.kmeans_assign": ("gflops", 2 * 16384 * 512 * 96),
        "anchor.numpy_matmul": ("gflops", 2 * 512 * 512 * 512),
    }
    f32_scan_qps = 1.0 / best["kernel.masked_exact_topk"]
    rows: dict = {}
    for name in cases:
        s = best[name]
        unit, amount = work[name]
        if name == "anchor.numpy_matmul":
            tail = "machine_speed_anchor"
            extra = {}
        elif name == "host.gather_rerank":
            tail = "removed_host_rerank_comparator"
            extra = {}
        elif name == "kernel.kmeans_assign":
            tail = f"pallas_agree_{agree:.3f}"
            extra = {"pallas_agree": agree}
        else:
            tail = f"pallas_delta_{delta[name]:.2e}"
            extra = {"pallas_delta": delta[name]}
        if name == "kernel.gather_rerank":
            # same-window paired ratio vs the removed NumPy host rerank
            extra["host_qps"] = 1.0 / best["host.gather_rerank"]
            extra["speedup_vs_host"] = best["host.gather_rerank"] / s
            tail += f"_vs_host_{extra['speedup_vs_host']:.2f}x"
        if name in quant_extras:
            extra.update(quant_extras[name])
            extra["speedup_vs_f32"] = (1.0 / s) / f32_scan_qps
            tail += (
                f"_vs_f32_{extra['speedup_vs_f32']:.2f}x"
                f"_guard_recall_{extra['recall_post_guard']:.3f}"
            )
        if name == "kernel.unified_masked_topk":
            extra["parity_ok"] = bool(unified_parity)
        emit(name, s * 1e6, f"{unit}_{amount/s/1e9:.2f}_{tail}")
        rows[name] = {"throughput_qps": 1.0 / s, **extra}

    if json_path:
        doc = {"meta": {"bench": "bench_kernels", "rounds": TIMING_ROUNDS}, "rows": rows}
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", dest="json_path", default="BENCH_kernels.json",
                    help="machine-readable output for scripts/check_bench.py "
                         "('' disables)")
    main(**vars(ap.parse_args()))
