#!/usr/bin/env bash
# CI entry point — tiered stages, each independently failable with its own
# log section (.github/workflows/ci.yml runs one stage per job):
#
#   --lint    ruff check over src/tests/benchmarks/scripts when ruff is
#             installed (rule set + line length pinned in ruff.toml so the
#             local run and CI agree byte-for-byte); otherwise degrades to
#             a python -m compileall syntax pass (the container gates
#             optional tooling — CI images install ruff, minimal dev boxes
#             may not).  Also fails if any Python cache artifact
#             (__pycache__/, .pytest_cache/, *.pyc) is ever TRACKED by
#             git — .gitignore keeps them out, this keeps them out
#             forever.  Finally runs scripts/check_docs.py: every repo
#             path and public symbol referenced by README.md or
#             docs/architecture.md must exist in the tree (AST-harvested
#             symbol universe), so the documentation cannot rot silently.
#   --tier1   kernel-parity gate first (pytest -m "kernels and not slow":
#             every op in kernels/ops.py, Pallas-interpret vs ref.py,
#             including the masked ops' and the multi-mask (Q, N)-plane
#             ops' edge cases), then the full tier-1 suite (pytest -x -q,
#             slow cases deselected per pytest.ini).
#   --chaos   serving-tier failover suite (pytest -m chaos): kill an
#             executor mid-wave (heartbeat-dead while HOLDING fragments)
#             and between waves, and prove zero queries are lost — results
#             at exact parity with a healthy run via lease re-dispatch.
#             The cases also run inside --tier1 (they are not slow-marked);
#             this stage re-runs them in isolation so failover regressions
#             get their own red CI job instead of hiding in the suite.
#   --cache   serving-tier cache hierarchy suite (pytest -m cache): the
#             shard-probe and semantic result caches — snapshot-commit
#             invalidation (refresh/compact can never serve stale),
#             time-travel isolation, LRU byte bounds, bit parity on every
#             hit, degraded-answer keying, admission interplay (a semantic
#             hit consumes no token-bucket budget), and the chaos × cache
#             crossover.  Like --chaos, the cases also run inside --tier1;
#             this stage re-runs them in isolation so a cache-coherence
#             regression gets its own red CI job instead of hiding in the
#             suite.
#   --bench   benchmark smoke + regression gate, TWO bench records:
#               bench_query_paths --tiny  -> BENCH_query_paths.json
#               bench_kernels             -> BENCH_kernels.json
#             Stale records are deleted first and each file must exist
#             non-empty after its run — a bench that crashes before
#             writing its record fails the stage loudly instead of letting
#             check_bench green-light leftover data (check_bench itself
#             also exits 2 on a missing/empty/row-less input).
#             scripts/check_bench.py gates both files against their
#             committed baselines (benchmarks/baselines/<same name>):
#             broken batched/sequential parity, batched throughput not
#             above sequential, filtered recall-vs-oracle < 0.95, zone
#             pruning not reducing fragments, the heterogeneous-filter
#             row (table2.filtered_hetero) not beating the
#             per-predicate-group path in its interleaved timing window
#             or not reducing kernel dispatches, the mixed-flavor row
#             (table2.filtered_mixed_flavor) not completing in EXACTLY
#             one kernel dispatch per shard / not beating the
#             two-dispatch split-flavor path in its paired
#             executor-level window / diverging from it, throughput
#             regression vs baseline on the kernel.* rows (35% noise
#             budget; machine factor pinned by the pure-numpy anchor.*
#             row, so even a uniform kernel regression is caught — table2
#             rows are never wall-clock-gated: they ride the scheduler and
#             swing >2x with load, so they gate on same-window ratios and
#             recall), ANY recall drop vs the baseline, or a baseline row
#             missing from the run.
#
# No stage flags (or --all) runs every stage in order.
#
# Updating a benchmark baseline (after an intentional perf/recall change):
#   PYTHONPATH=src python -m benchmarks.bench_query_paths --tiny \
#       --json benchmarks/baselines/BENCH_query_paths.json
#   PYTHONPATH=src python -m benchmarks.bench_kernels \
#       --json benchmarks/baselines/BENCH_kernels.json
# then commit the new baseline alongside the change that justifies it, and
# say why in the commit message.  Never refresh a baseline to silence a
# regression you cannot explain.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_lint=false
run_tier1=false
run_chaos=false
run_cache=false
run_bench=false
if [ "$#" -eq 0 ]; then
  run_lint=true; run_tier1=true; run_chaos=true; run_cache=true; run_bench=true
fi
for arg in "$@"; do
  case "$arg" in
    --lint)  run_lint=true ;;
    --tier1) run_tier1=true ;;
    --chaos) run_chaos=true ;;
    --cache) run_cache=true ;;
    --bench) run_bench=true ;;
    --all)   run_lint=true; run_tier1=true; run_chaos=true; run_cache=true; run_bench=true ;;
    *) echo "usage: $0 [--lint] [--tier1] [--chaos] [--cache] [--bench] [--all]" >&2; exit 2 ;;
  esac
done

if $run_lint; then
  echo "== lint =="
  if command -v git >/dev/null 2>&1 && [ -d .git ]; then
    tracked_caches=$(git ls-files | grep -E '(^|/)(__pycache__|\.pytest_cache)/|\.pyc$' || true)
    if [ -n "$tracked_caches" ]; then
      echo "LINT-ERROR: Python cache artifacts are tracked by git:" >&2
      echo "$tracked_caches" >&2
      echo "  (git rm -r --cached them; .gitignore already excludes them)" >&2
      exit 1
    fi
  fi
  if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks scripts
  else
    echo "ruff not installed — falling back to a compileall syntax pass"
    python -m compileall -q src tests benchmarks scripts
  fi
  # the docs front door must not rot: every path / public symbol referenced
  # by README.md and docs/architecture.md has to exist in the tree
  python scripts/check_docs.py
fi

if $run_tier1; then
  echo "== tier-1: kernel parity (Pallas-interpret vs ref oracle) =="
  python -m pytest -q -m "kernels and not slow"
  echo "== tier-1: full suite =="
  python -m pytest -x -q
fi

if $run_chaos; then
  echo "== chaos: executor failover (kill mid-wave, zero queries lost) =="
  python -m pytest -q -m chaos
fi

if $run_cache; then
  echo "== cache: serving-tier hierarchy (invalidation, parity, LRU bounds) =="
  python -m pytest -q -m cache
fi

if $run_bench; then
  echo "== benchmark smoke (batched + filtered query paths, kernels) =="
  # never let a stale record from an earlier run satisfy the gate
  rm -f BENCH_query_paths.json BENCH_kernels.json
  python -m benchmarks.bench_query_paths --tiny --json BENCH_query_paths.json
  python -m benchmarks.bench_kernels --json BENCH_kernels.json
  for rec in BENCH_query_paths.json BENCH_kernels.json; do
    if [ ! -s "$rec" ]; then
      echo "BENCH-ERROR: $rec missing or empty — the bench run crashed before writing it" >&2
      exit 1
    fi
  done
  echo "== benchmark regression gate =="
  python scripts/check_bench.py BENCH_query_paths.json BENCH_kernels.json \
    --baseline benchmarks/baselines/BENCH_query_paths.json \
    --baseline benchmarks/baselines/BENCH_kernels.json
fi
