#!/usr/bin/env bash
# CI entry point — tiered stages, each independently failable with its own
# log section (.github/workflows/ci.yml runs one stage per job):
#
#   --lint    ruff check over src/tests/benchmarks/scripts when ruff is
#             installed; otherwise degrades to a python -m compileall
#             syntax pass (the container gates optional tooling — CI
#             images install ruff, minimal dev boxes may not).
#   --tier1   kernel-parity gate first (pytest -m "kernels and not slow":
#             every op in kernels/ops.py, Pallas-interpret vs ref.py,
#             including the masked ops' edge cases), then the full tier-1
#             suite (pytest -x -q, slow cases deselected per pytest.ini).
#   --bench   benchmark smoke + regression gate: bench_query_paths --tiny
#             writes BENCH_query_paths.json (throughput + recall per row);
#             scripts/check_bench.py fails on broken batched/sequential
#             parity, batched throughput not above sequential, filtered
#             recall-vs-oracle < 0.95, zone pruning not reducing fragments,
#             >20% throughput regression on the kernel-dominated filtered
#             row vs the committed baseline (median-ratio machine-factor
#             normalization keeps a uniformly slower runner from tripping
#             the gate; beam-driven rows are recall/speedup-gated only —
#             their wall clock is load-sensitive), ANY recall drop vs the
#             baseline, or a baseline row missing from the run.
#
# No stage flags (or --all) runs every stage in order.
#
# Updating the benchmark baseline (after an intentional perf/recall change):
#   PYTHONPATH=src python -m benchmarks.bench_query_paths --tiny \
#       --json benchmarks/baselines/BENCH_query_paths.json
# then commit the new baseline alongside the change that justifies it, and
# say why in the commit message.  Never refresh the baseline to silence a
# regression you cannot explain.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

run_lint=false
run_tier1=false
run_bench=false
if [ "$#" -eq 0 ]; then
  run_lint=true; run_tier1=true; run_bench=true
fi
for arg in "$@"; do
  case "$arg" in
    --lint)  run_lint=true ;;
    --tier1) run_tier1=true ;;
    --bench) run_bench=true ;;
    --all)   run_lint=true; run_tier1=true; run_bench=true ;;
    *) echo "usage: $0 [--lint] [--tier1] [--bench] [--all]" >&2; exit 2 ;;
  esac
done

if $run_lint; then
  echo "== lint =="
  if command -v ruff >/dev/null 2>&1; then
    ruff check src tests benchmarks scripts
  else
    echo "ruff not installed — falling back to a compileall syntax pass"
    python -m compileall -q src tests benchmarks scripts
  fi
fi

if $run_tier1; then
  echo "== tier-1: kernel parity (Pallas-interpret vs ref oracle) =="
  python -m pytest -q -m "kernels and not slow"
  echo "== tier-1: full suite =="
  python -m pytest -x -q
fi

if $run_bench; then
  echo "== benchmark smoke (batched + filtered query paths) =="
  python -m benchmarks.bench_query_paths --tiny --json BENCH_query_paths.json
  echo "== benchmark regression gate =="
  python scripts/check_bench.py BENCH_query_paths.json \
    --baseline benchmarks/baselines/BENCH_query_paths.json
fi
