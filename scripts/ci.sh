#!/usr/bin/env bash
# CI entry point: tier-1 tests + a query-path benchmark smoke.
#
# The benchmark smoke runs bench_query_paths in --tiny mode; it exits
# non-zero if the batched probe pipeline is not faster than sequential
# probes, if filtered-probe recall against the brute-force post-filter
# oracle drops below 0.95 on the smoke corpus, or if zone-map pruning
# stops reducing dispatched shard fragments on a high-selectivity
# predicate — so regressions on both hot query paths fail CI.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke (batched + filtered query paths) =="
python -m benchmarks.bench_query_paths --tiny
