#!/usr/bin/env bash
# CI entry point: tier-1 tests + a query-path benchmark smoke.
#
# The benchmark smoke runs bench_query_paths in --tiny mode; it exits
# non-zero if the batched probe pipeline is not faster than sequential
# probes, so throughput regressions on the hot query path fail CI too.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== benchmark smoke (batched query path) =="
python -m benchmarks.bench_query_paths --tiny
