"""Regenerate EXPERIMENTS.md's embedded tables from results/*.jsonl.

    PYTHONPATH=src python scripts/embed_tables.py
"""

import re
import subprocess
import sys

out = subprocess.run(
    [sys.executable, "-m", "repro.analysis.report"],
    capture_output=True, text=True, check=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
).stdout
perf_idx = out.index("## §Perf")
main_tables = out[:perf_idx].rstrip()
perf_tables = out[perf_idx:].split("\n", 1)[1].strip()

content = open("EXPERIMENTS.md").read()
content = re.sub(
    r"<!-- BEGIN GENERATED TABLES -->.*?<!-- END GENERATED TABLES -->",
    "<!-- BEGIN GENERATED TABLES -->\n" + main_tables + "\n<!-- END GENERATED TABLES -->",
    content,
    flags=re.S,
)
content = re.sub(
    r"<!-- BEGIN PERF TABLE -->.*?<!-- END PERF TABLE -->",
    "<!-- BEGIN PERF TABLE -->\n" + perf_tables + "\n<!-- END PERF TABLE -->",
    content,
    flags=re.S,
)
open("EXPERIMENTS.md", "w").write(content)
print("EXPERIMENTS.md tables refreshed:", len(content.splitlines()), "lines")
