#!/usr/bin/env python
"""Documentation-reference gate for CI (scripts/ci.sh --lint).

Reads README.md and docs/architecture.md and fails when either references
something that does not exist in the repo, so the documentation front door
cannot rot silently as the code moves:

  - **paths** — any ``src/...``, ``scripts/...``, ``docs/...``,
    ``examples/...``, ``benchmarks/...`` or ``tests/...`` token (inline or
    in a fenced block) must exist on disk;
  - **file names** — a backticked bare file name (``planner.py``,
    ``ci.sh``, ``ruff.toml``) must exist somewhere in the repo;
  - **symbols** — a backticked reference that looks like code is resolved
    against a universe of names harvested by AST-parsing every Python file
    under ``src/repro``, ``scripts`` and ``benchmarks``:

      * ``CamelCase`` must be a known class;
      * ``ALL_CAPS`` must be a known constant;
      * ``snake_case`` (with an underscore) must be a known function,
        method, attribute, field or parameter;
      * dotted chains (``planner.resolve``, ``VamanaGraph.search_masked``,
        ``ProbeReport.plan``) are checked component-wise when the first
        component is a known module or class — every later component must
        be a known name.

    Anything else (prose, flags, bench row ids like ``table2.filtered``,
    hyphenated blob names, expressions) is deliberately skipped: the gate
    is for rot, not for style, so it only judges tokens it can resolve
    with confidence.

Exit codes: 0 all references resolve, 1 at least one is dangling,
2 a documented file itself is missing.
"""

from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path

DOC_FILES = ("README.md", "docs/architecture.md")
SOURCE_ROOTS = ("src/repro", "scripts", "benchmarks")

PATH_RE = re.compile(
    r"(?:src|scripts|docs|examples|benchmarks|tests)/[A-Za-z0-9_./-]+"
)
FILENAME_RE = re.compile(r"^[A-Za-z0-9_.-]+\.(?:py|sh|md|json|toml|ini|yml)$")
INLINE_CODE_RE = re.compile(r"`([^`]+)`")
FENCE_RE = re.compile(r"```.*?```", re.S)
IDENT = r"[A-Za-z_][A-Za-z0-9_]*"
CHAIN_RE = re.compile(rf"^{IDENT}(?:\.{IDENT})*$")
CAMEL_RE = re.compile(r"^[A-Z][A-Za-z0-9]*$")
ALL_CAPS_RE = re.compile(r"^[A-Z][A-Z0-9_]+$")
SNAKE_RE = re.compile(r"^[a-z][a-z0-9]*(?:_[a-z0-9]+)+$")


def harvest(root: Path) -> tuple[set, set, set]:
    """AST-walk the source tree: (module stems, class names, all names)."""
    modules: set = set()
    classes: set = set()
    names: set = set()
    for src_root in SOURCE_ROOTS:
        base = root / src_root
        if not base.is_dir():
            continue
        for py in base.rglob("*.py"):
            modules.add(py.stem)
            for part in py.relative_to(root).parts[:-1]:
                modules.add(part)
            try:
                tree = ast.parse(py.read_text())
            except SyntaxError as e:  # a broken source file is its own bug
                print(f"DOCS-ERROR: cannot parse {py}: {e}", file=sys.stderr)
                sys.exit(2)
            for node in ast.walk(tree):
                if isinstance(node, ast.ClassDef):
                    classes.add(node.name)
                    names.add(node.name)
                elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    names.add(node.name)
                    a = node.args
                    for arg in (
                        a.args + a.posonlyargs + a.kwonlyargs
                        + ([a.vararg] if a.vararg else [])
                        + ([a.kwarg] if a.kwarg else [])
                    ):
                        names.add(arg.arg)
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            names.add(t.id)
                        elif isinstance(t, ast.Attribute):
                            names.add(t.attr)  # self.x = ... style attributes
                elif isinstance(node, ast.AnnAssign):
                    if isinstance(node.target, ast.Name):
                        names.add(node.target.id)  # dataclass fields
                    elif isinstance(node.target, ast.Attribute):
                        names.add(node.target.attr)
    return modules, classes, names


def clean_span(span: str) -> str:
    """A backticked span down to its leading reference: drop a call's
    argument list, an assignment's right side, trailing punctuation."""
    for stop in ("(", "=", " "):
        idx = span.find(stop)
        if idx >= 0:
            span = span[:idx]
    return span.strip().rstrip(".,:;")


def check_file(
    doc: Path, root: Path, modules: set, classes: set, names: set
) -> list:
    failures = []
    text = doc.read_text()
    rel = doc.relative_to(root)

    for m in PATH_RE.finditer(text):
        token = m.group(0).rstrip(".,:;)")
        if not (root / token).exists():
            failures.append(f"{rel}: path `{token}` does not exist")

    # inline spans only — fenced blocks are full example programs whose
    # identifiers (loop variables, kwargs) are not documentation claims
    for m in INLINE_CODE_RE.finditer(FENCE_RE.sub("", text)):
        span = clean_span(m.group(1))
        if not span or "/" in span:
            continue  # paths were already handled above
        if ALL_CAPS_RE.match(span) and m.group(1).startswith(span + "="):
            continue  # an env-var assignment (`PYTHONPATH=src ...`), not a constant
        if FILENAME_RE.match(span):
            if not any(root.rglob(span)):
                failures.append(f"{rel}: file `{span}` not found in the repo")
            continue
        if not CHAIN_RE.match(span):
            continue
        parts = span.split(".")
        if len(parts) == 1:
            tok = parts[0]
            if CAMEL_RE.match(tok) and any(c.islower() for c in tok):
                if tok not in classes:
                    failures.append(f"{rel}: class `{tok}` not found")
            elif ALL_CAPS_RE.match(tok):
                if tok not in names:
                    failures.append(f"{rel}: constant `{tok}` not found")
            elif SNAKE_RE.match(tok):
                if tok not in names:
                    failures.append(f"{rel}: symbol `{tok}` not found")
        elif parts[0] in modules or parts[0] in classes:
            for comp in parts[1:]:
                if comp not in names:
                    failures.append(
                        f"{rel}: `{span}` — member `{comp}` not found"
                    )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root", default=str(Path(__file__).resolve().parent.parent),
        help="repo root (default: the checkout containing this script)",
    )
    args = ap.parse_args(argv)
    root = Path(args.root)

    modules, classes, names = harvest(root)
    failures = []
    for doc_rel in DOC_FILES:
        doc = root / doc_rel
        if not doc.is_file():
            print(f"DOCS-ERROR: {doc_rel} is missing", file=sys.stderr)
            return 2
        failures += check_file(doc, root, modules, classes, names)

    if failures:
        print(f"DOCS-CHECK: {len(failures)} dangling reference(s):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"DOCS-CHECK: ok ({', '.join(DOC_FILES)} — all references resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
