#!/usr/bin/env python
"""Benchmark-regression gate for CI (scripts/ci.sh --bench).

Reads the machine-readable records the benchmark runs write — any number
of them, each paired with its own committed baseline (currently
``BENCH_query_paths.json`` from ``benchmarks/bench_query_paths.py`` and
``BENCH_kernels.json`` from ``benchmarks/bench_kernels.py``) — and fails
with a readable report when a run regresses, replacing the ad-hoc asserts
that used to live inside the bench scripts:

Absolute gates (hold regardless of any baseline):
  - ``table2.batched``: per-query parity with sequential probes
    (``parity_ok``) and throughput strictly above the sequential path
    (``speedup > 1``);
  - ``table2.filtered``: recall vs the brute-force post-filter oracle
    >= 0.95, and zone-map pruning still reducing dispatched shard
    fragments (fewer fragments than the unfiltered batch, or whole shards
    pruned) on the high-selectivity predicate (``speedup_vs_oracle`` is
    recorded but not gated — at tiny CI scale the one-wave oracle
    legitimately outruns the two-wave distributed pipeline; the masked
    kernels' own perf gates live in the kernels file);
  - ``table2.filtered_hetero`` (8+ distinct predicates in one batch):
    recall vs oracle >= 0.95, hits identical to the legacy
    per-predicate-group path (``parity_ok``), FEWER masked-kernel
    dispatches than that path (``kernel_dispatches < grouped_dispatches``
    — the whole point of the (Q, N) mask-plane kernels), and throughput
    strictly above it (``speedup_vs_grouped > 1``; both paths are timed in
    the same window, so ambient load cancels in the ratio).
  - ``table2.filtered_mixed_flavor`` (batch mixing exact- and PQ-flavor
    plans with heterogeneous predicates): recall vs oracle >= 0.95, hits
    identical to the two-dispatch split-flavor path (``parity_ok``),
    EXACTLY one kernel dispatch per shard (``kernel_dispatches ==
    probe_fragments`` — the unified exact/PQ kernel's contract), fewer
    dispatches than the split path, and the fragment-level Stage A faster
    than it (``speedup_vs_split > 1``; both modes timed on the same
    executor in the same interleaved window).
  - ``table2.filtered_lowsel_bigshard`` (low-selectivity predicate on a
    shard above the planner's masked-scan cap): the MaskedBeam traversal
    must beat the replayed over-fetched postfilter plan in its same-window
    paired timing (``speedup_vs_postfilter > 1``), hold recall vs the scan
    oracle >= 0.95, and stay within its dispatch budget
    (``kernel_dispatches <= probe_fragments`` — traversal rows cost no
    masked-kernel dispatch, at most ONE fused fallback per fragment);
    vacuous-run guards: the shard really above ``exact_scan_cap``, every
    batch row really traversed (``masked_beam_rows == batch_queries``
    with ``plan_mbeam``), and not every traversal row allowed to fall
    back to the exact scan.
  - ``table2.freshness`` (probe immediately after an append, NO index
    refresh): an unindexed tail must actually be present (``tail_rows >
    0`` and ``stale``), recall vs the fresh scan oracle >= 0.95, ZERO
    silently-dropped rows (``unindexed_rows == 0`` — the stale-read
    window the fresh-tail tier closes), and exactly one plan op per
    unindexed row group (``tail_plan_ops == tail_row_groups``).
  - ``table2.overload`` (two tenants at ~2x serving capacity, one abusive):
    offered load actually over capacity (``overload_factor >= 1.5``), the
    well-behaved tenant's deadline hit-rate >= 0.9, the ABUSIVE tenant
    absorbing the rejections (``abusive_rejected > well_rejected``), and
    the submission queue staying bounded (``queue_bounded``) — the
    serving tier's admission-control contract.  Never wall-clock gated:
    the row's qps rides the scheduler like every other table2 row.
  - ``table2.zipfian`` (Zipf-distributed repeat traffic through the
    two-layer cache hierarchy): vacuous-run guards first (the stream must
    be longer than the query pool so repeats actually occur, and the
    full-repeat parity pass must take >0 shard-cache hits — otherwise
    ``parity_ok`` compares the uncached path with itself), then both hit
    rates > 0 (``semantic_hit_rate``, ``shard_hit_rate``), warm p50
    strictly below cold p50 (same interleaved window, so load cancels),
    recall vs the scan oracle >= 0.95, bit parity with the cache-off path
    (``parity_ok``), >0 ``invalidations`` after the mid-bench refresh,
    and ZERO ``stale_hits`` after the snapshot commit.  Never wall-clock
    gated against the baseline — warm-vs-cold is its own paired timing.

  - ``kernel.gather_rerank``: the device pool rerank must beat the removed
    NumPy host rerank it replaced (``speedup_vs_host > 1``; both sides are
    timed in the same interleaved window, so load cancels);
  - ``kernel.unified_masked_topk``: fused-dispatch hits identical to the
    split-flavor exact+ADC dispatches (``parity_ok``);
  - quantized scan rows (``kernel.masked_exact_topk_bf16`` / ``_int8``):
    recall AFTER the full-precision gather-rerank guard >= 0.95
    (``recall_post_guard``), and speed vs the f32 scan gated by backend —
    ``speedup_vs_f32 > 1`` when ``quantized_native`` (TPU), else the 0.5x
    plumbing floor (CPU scoring dequantizes to f32, so quantization buys
    bandwidth/footprint there, not FLOPs).

Baseline gates (vs the committed baseline, benchmarks/baselines/):
  - a THROUGHPUT-GATED row's ``throughput_qps`` dropping more than
    ``--max-regress`` (default 20%) below the baseline, after normalizing
    by the machine factor — the MEDIAN of cur/base throughput ratios
    across ALL rows of the same bench file — or, when the file carries
    ``anchor.*`` rows (fixed pure-numpy work no repo change can affect),
    across the anchors alone, so even a uniform regression of every gated
    row is caught.  The baseline was recorded on
    one machine and CI runs on another, so a uniform speed difference must
    divide out; a real regression changes one path's ratio and sticks out
    from the median.  Throughput-gated rows: every ``kernel.*`` row
    (single-process compute, no beam search or scheduler in the loop;
    kernel rows use the wider ``KERNEL_MAX_REGRESS`` budget — see its
    comment).  NO table2 row is wall-clock gated: every one rides the
    coordinator/scheduler (5 ms poll quantization per wave) and swings
    >2x with ambient load even best-of-N (measured live) — gating those
    on wall clock makes CI cry wolf; batched and hetero are instead gated
    on their speedup ratios (numerator and denominator timed in the same
    window, so load cancels).  All rows still feed the
    machine factor and the recall gate.
  - baseline drift, BOTH directions: a row present in the baseline but
    missing from the current run (a silently dropped row would otherwise
    un-gate itself), and a row the bench now emits that is missing from
    the committed baseline (a stale baseline would otherwise exempt the
    new row from every baseline-relative gate — regenerate the baseline
    alongside the change that added the row).
  - ANY row's ``recall`` dropping below the baseline at all (recall is
    deterministic under the bench's fixed seeds, so any drop is a real
    behavior change, not timing noise).

A missing, empty, or row-less input file is an ERROR (exit 2), not a
pass: a bench run that crashed before writing its record must fail the
gate loudly instead of green-lighting stale or absent data.

Baseline update procedure: see the header of scripts/ci.sh.

Exit status: 0 = clean, 1 = regression(s) (each printed on its own
``BENCH-REGRESSION:`` line), 2 = bad invocation / unreadable input.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

DEFAULT_MAX_REGRESS = 0.20
# kernel.* rows time bare eager matmuls whose wall clock floats ±20% on a
# shared runner even after interleaved best-of-16 and the machine-factor
# normalization (measured across repeated runs) — a 20% budget flakes, so
# they get a wider one.  A genuine kernel regression (an accidentally
# quadratic mask path, a lost fusion) costs 2x+, far past 35%.
KERNEL_MAX_REGRESS = 0.35
RECALL_EPS = 1e-9  # float-representation slack only: ANY real drop fails
FILTERED_MIN_RECALL = 0.95
# quantized scan flavors (bf16/int8): recall AFTER the mandatory
# full-precision gather-rerank guard must stay >= this floor — the guard
# exists precisely so reduced-precision scanning never costs recall
QUANT_MIN_RECALL = 0.95
# speed: on a native backend (TPU) a quantized scan must beat the f32 scan
# outright; on CPU the honest scoring path dequantizes to f32 (quantization
# buys memory footprint, not CPU FLOPs — measured ~0.6-0.7x), so the gate
# only catches a pathological slowdown of the quantized plumbing
QUANT_NON_NATIVE_SPEED_FLOOR = 0.5
QUANT_ROWS = ("kernel.masked_exact_topk_bf16", "kernel.masked_exact_topk_int8")
# Wall-clock baseline gating is reserved for the kernels file: its rows
# are single-process compute timed in interleaved rounds against a
# pure-numpy anchor.  NO table2 row is wall-clock gated — every one of
# them rides the coordinator/scheduler (5 ms poll quantization per wave)
# and swings >2x with ambient load (measured live, including
# table2.filtered, which PR 3 briefly wall-clock-gated) — they gate on
# load-cancelling SAME-WINDOW ratios instead: batched speedup vs
# sequential, filtered speedup vs the brute-force oracle, hetero speedup
# vs the per-predicate-group path + its dispatch count, plus recall.
THROUGHPUT_GATED = ()
THROUGHPUT_GATED_PREFIXES = ("kernel.",)
DEFAULT_BASELINE_DIR = "benchmarks/baselines"


def _throughput_gated(name: str) -> bool:
    return name in THROUGHPUT_GATED or name.startswith(THROUGHPUT_GATED_PREFIXES)


def _regress_budget(name: str, max_regress: float) -> float:
    if name.startswith(THROUGHPUT_GATED_PREFIXES):
        return max(max_regress, KERNEL_MAX_REGRESS)
    return max_regress


def check(
    current: dict,
    baseline: Optional[dict],
    max_regress: float = DEFAULT_MAX_REGRESS,
) -> List[str]:
    """Pure gate logic for ONE (current, baseline) document pair: returns a
    list of human-readable failures (empty = clean).  Split from main() so
    the unit tests can doctor JSON documents and assert specific injected
    regressions are caught."""
    failures: List[str] = []
    rows = current.get("rows", {})
    base_rows = (baseline or {}).get("rows", {})

    batched = rows.get("table2.batched")
    if batched is not None:
        if not batched.get("parity_ok", True):
            failures.append(
                "table2.batched: batched hits diverge from sequential probes"
            )
        if batched.get("speedup", 0.0) <= 1.0:
            failures.append(
                f"table2.batched: batched throughput "
                f"{batched.get('throughput_qps', 0.0):.1f} qps is not above the "
                f"sequential path {batched.get('seq_qps', 0.0):.1f} qps"
            )
    filtered = rows.get("table2.filtered")
    if filtered is not None:
        if filtered.get("recall", 0.0) < FILTERED_MIN_RECALL:
            failures.append(
                f"table2.filtered: recall vs oracle {filtered.get('recall', 0.0):.3f} "
                f"< {FILTERED_MIN_RECALL}"
            )
        if (
            filtered.get("probe_fragments", 0)
            >= filtered.get("unfiltered_fragments", 0)
            and filtered.get("shards_pruned", 0) == 0
        ):
            failures.append(
                "table2.filtered: zone-map pruning dispatched no fewer shard "
                f"fragments ({filtered.get('probe_fragments')} vs unfiltered "
                f"{filtered.get('unfiltered_fragments')}) on a high-selectivity "
                "predicate"
            )
        # (speedup_vs_oracle is informational, NOT gated: at the tiny CI
        # scale the one-wave brute-force oracle legitimately beats the
        # two-wave distributed pipeline on wall clock — the masked
        # kernels' own perf is gated in BENCH_kernels.json instead)
    hetero = rows.get("table2.filtered_hetero")
    if hetero is not None:
        if hetero.get("recall", 0.0) < FILTERED_MIN_RECALL:
            failures.append(
                f"table2.filtered_hetero: recall vs oracle "
                f"{hetero.get('recall', 0.0):.3f} < {FILTERED_MIN_RECALL}"
            )
        if not hetero.get("parity_ok", True):
            failures.append(
                "table2.filtered_hetero: mask-plane hits diverge from the "
                "per-predicate-group path"
            )
        if hetero.get("kernel_dispatches", 0) >= hetero.get("grouped_dispatches", 0):
            failures.append(
                "table2.filtered_hetero: mask-plane path issued no fewer kernel "
                f"dispatches ({hetero.get('kernel_dispatches')}) than the "
                f"per-predicate-group path ({hetero.get('grouped_dispatches')}) "
                f"on {hetero.get('distinct_filters', '?')} distinct predicates"
            )
        if hetero.get("speedup_vs_grouped", 0.0) <= 1.0:
            failures.append(
                f"table2.filtered_hetero: mask-plane throughput "
                f"{hetero.get('throughput_qps', 0.0):.1f} qps is not above the "
                f"per-predicate-group path {hetero.get('grouped_qps', 0.0):.1f} qps"
            )
    mixed = rows.get("table2.filtered_mixed_flavor")
    if mixed is not None:
        if mixed.get("recall", 0.0) < FILTERED_MIN_RECALL:
            failures.append(
                f"table2.filtered_mixed_flavor: recall vs oracle "
                f"{mixed.get('recall', 0.0):.3f} < {FILTERED_MIN_RECALL}"
            )
        if not mixed.get("parity_ok", True):
            failures.append(
                "table2.filtered_mixed_flavor: unified-kernel hits diverge "
                "from the split-flavor path"
            )
        if mixed.get("kernel_dispatches", -1) != mixed.get("probe_fragments", 0):
            failures.append(
                "table2.filtered_mixed_flavor: mixed-flavor fragments did not "
                f"complete in exactly one kernel dispatch per shard "
                f"({mixed.get('kernel_dispatches')} dispatches for "
                f"{mixed.get('probe_fragments')} fragments)"
            )
        if mixed.get("kernel_dispatches", 0) >= mixed.get("split_dispatches", 0):
            failures.append(
                "table2.filtered_mixed_flavor: unified kernel issued no fewer "
                f"dispatches ({mixed.get('kernel_dispatches')}) than the "
                f"split-flavor path ({mixed.get('split_dispatches')})"
            )
        if mixed.get("speedup_vs_split", 0.0) <= 1.0:
            failures.append(
                f"table2.filtered_mixed_flavor: unified fragment Stage A "
                f"(speedup_vs_split {mixed.get('speedup_vs_split', 0.0):.2f}x) "
                "is not faster than the two-dispatch split-flavor path"
            )
    bigshard = rows.get("table2.filtered_lowsel_bigshard")
    if bigshard is not None:
        # vacuous-run guards first: the row gates nothing unless the shard
        # is really above the masked-scan cap AND every batch row really
        # took the MaskedBeam traversal
        if bigshard.get("shard_rows", 0) <= bigshard.get("exact_scan_cap", 0):
            failures.append(
                f"table2.filtered_lowsel_bigshard: shard has "
                f"{bigshard.get('shard_rows', 0)} rows, not above the "
                f"masked-scan cap {bigshard.get('exact_scan_cap', 0)} — the "
                "MaskedBeam band was never exercised"
            )
        if not bigshard.get("plan_mbeam", False) or (
            bigshard.get("masked_beam_rows", 0)
            < bigshard.get("batch_queries", -1)
        ):
            failures.append(
                f"table2.filtered_lowsel_bigshard: only "
                f"{bigshard.get('masked_beam_rows', 0)} of "
                f"{bigshard.get('batch_queries', 0)} batch rows took the "
                f"MaskedBeam traversal (plan_mbeam="
                f"{bigshard.get('plan_mbeam', False)}) — the row is not "
                "measuring the predicate-aware path"
            )
        if bigshard.get("masked_beam_fallbacks", 0) >= max(
            bigshard.get("masked_beam_rows", 0), 1
        ):
            failures.append(
                f"table2.filtered_lowsel_bigshard: every traversal row "
                f"({bigshard.get('masked_beam_fallbacks', 0)}) under-delivered "
                "into the exact fallback — the timing just compares the "
                "fallback path with itself"
            )
        if bigshard.get("recall", 0.0) < FILTERED_MIN_RECALL:
            failures.append(
                f"table2.filtered_lowsel_bigshard: recall vs oracle "
                f"{bigshard.get('recall', 0.0):.3f} < {FILTERED_MIN_RECALL}"
            )
        if bigshard.get("speedup_vs_postfilter", 0.0) <= 1.0:
            failures.append(
                f"table2.filtered_lowsel_bigshard: MaskedBeam throughput "
                f"{bigshard.get('throughput_qps', 0.0):.1f} qps is not above "
                f"the replayed postfilter path "
                f"{bigshard.get('postfilter_qps', 0.0):.1f} qps (same-window "
                "paired timing)"
            )
        if bigshard.get("kernel_dispatches", 0) > bigshard.get(
            "probe_fragments", 0
        ):
            failures.append(
                f"table2.filtered_lowsel_bigshard: "
                f"{bigshard.get('kernel_dispatches', 0)} masked-kernel "
                f"dispatches for {bigshard.get('probe_fragments', 0)} "
                "fragments — traversal rows must cost no dispatch beyond "
                "ONE fused fallback per fragment"
            )
    fresh = rows.get("table2.freshness")
    if fresh is not None:
        if fresh.get("tail_rows", 0) <= 0 or not fresh.get("stale", False):
            failures.append(
                "table2.freshness: the bench probed with no unindexed tail "
                f"present (tail_rows={fresh.get('tail_rows', 0)}, "
                f"stale={fresh.get('stale', False)}) — the staleness gate "
                "exercised nothing"
            )
        if fresh.get("recall", 0.0) < FILTERED_MIN_RECALL:
            failures.append(
                f"table2.freshness: recall vs the fresh scan oracle "
                f"{fresh.get('recall', 0.0):.3f} < {FILTERED_MIN_RECALL} with "
                "an unindexed tail present — appended rows are not searchable"
            )
        if fresh.get("unindexed_rows", -1) != 0:
            failures.append(
                f"table2.freshness: probe silently dropped "
                f"{fresh.get('unindexed_rows')} appended-but-unindexed rows "
                "(the pre-tail-tier stale-read window is back)"
            )
        if fresh.get("tail_plan_ops", -1) != fresh.get("tail_row_groups", 0):
            failures.append(
                f"table2.freshness: plan carried "
                f"{fresh.get('tail_plan_ops')} tail ops for "
                f"{fresh.get('tail_row_groups')} unindexed row groups — the "
                "one-ExactScan-per-tail-row-group contract broke"
            )

    overload = rows.get("table2.overload")
    if overload is not None:
        if overload.get("overload_factor", 0.0) < 1.5:
            failures.append(
                f"table2.overload: offered load was only "
                f"{overload.get('overload_factor', 0.0):.2f}x capacity — the "
                "bench did not actually overload the serving tier"
            )
        if overload.get("well_hit_rate", 0.0) < 0.9:
            failures.append(
                f"table2.overload: well-behaved tenant deadline hit-rate "
                f"{overload.get('well_hit_rate', 0.0):.2f} < 0.9 under an "
                "abusive co-tenant — admission control is not isolating tenants"
            )
        if overload.get("abusive_rejected", 0) <= overload.get("well_rejected", 0):
            failures.append(
                f"table2.overload: the abusive tenant absorbed "
                f"{overload.get('abusive_rejected', 0)} rejections vs the "
                f"well-behaved tenant's {overload.get('well_rejected', 0)} — "
                "the wrong tenant is paying for the overload"
            )
        if not overload.get("queue_bounded", False):
            failures.append(
                "table2.overload: the submission queue exceeded its bound "
                "under overload — backpressure is not holding"
            )

    zipf = rows.get("table2.zipfian")
    if zipf is not None:
        # vacuous-run guards: the row gates nothing unless the stream
        # actually repeated queries and the replay pass actually hit
        if zipf.get("stream_len", 0) <= zipf.get("pool_size", 0):
            failures.append(
                f"table2.zipfian: stream of {zipf.get('stream_len', 0)} over a "
                f"pool of {zipf.get('pool_size', 0)} never repeats a query — "
                "the cache hierarchy was never exercised"
            )
        if zipf.get("replay_cache_hits", 0) <= 0:
            failures.append(
                "table2.zipfian: the full-repeat parity pass took zero shard-"
                "cache hits — parity_ok compares the uncached path with itself"
            )
        if zipf.get("semantic_hit_rate", 0.0) <= 0.0:
            failures.append(
                f"table2.zipfian: semantic hit rate "
                f"{zipf.get('semantic_hit_rate', 0.0):.3f} is not > 0 under "
                "Zipfian repeats — the result cache never answered"
            )
        if zipf.get("shard_hit_rate", 0.0) <= 0.0:
            failures.append(
                f"table2.zipfian: shard-probe hit rate "
                f"{zipf.get('shard_hit_rate', 0.0):.3f} is not > 0 — Stage-A "
                "fragments were always recomputed"
            )
        if zipf.get("warm_p50_ms", float("inf")) >= zipf.get("cold_p50_ms", 0.0):
            failures.append(
                f"table2.zipfian: warm p50 {zipf.get('warm_p50_ms', 0.0):.2f} ms "
                f"is not below cold p50 {zipf.get('cold_p50_ms', 0.0):.2f} ms in "
                "the same interleaved window — the caches bought nothing"
            )
        if zipf.get("recall", 0.0) < FILTERED_MIN_RECALL:
            failures.append(
                f"table2.zipfian: recall vs oracle {zipf.get('recall', 0.0):.3f} "
                f"< {FILTERED_MIN_RECALL} — cached answers are degrading results"
            )
        if not zipf.get("parity_ok", False):
            failures.append(
                "table2.zipfian: cached probes diverged from the cache-off "
                "path — the cache changed results, not just latency"
            )
        if zipf.get("invalidations", 0) <= 0:
            failures.append(
                "table2.zipfian: the post-refresh probe saw zero cache "
                "invalidations — the snapshot commit is not reaching the caches"
            )
        if zipf.get("stale_hits", -1) != 0:
            failures.append(
                f"table2.zipfian: {zipf.get('stale_hits', -1)} stale answers "
                "served after the refresh commit — snapshot invalidation broke"
            )

    gather = rows.get("kernel.gather_rerank")
    if gather is not None:
        if gather.get("speedup_vs_host", 0.0) <= 1.0:
            failures.append(
                f"kernel.gather_rerank: device pool rerank "
                f"(speedup_vs_host {gather.get('speedup_vs_host', 0.0):.2f}x) is "
                "not faster than the removed NumPy host rerank it replaced "
                "(same-window paired timing)"
            )
    unified_row = rows.get("kernel.unified_masked_topk")
    if unified_row is not None and not unified_row.get("parity_ok", True):
        failures.append(
            "kernel.unified_masked_topk: fused-dispatch hits diverge from the "
            "split-flavor exact+ADC dispatches — the unified kernel changed "
            "results, not just dispatch count"
        )
    for name in QUANT_ROWS:
        qrow = rows.get(name)
        if qrow is None:
            continue
        if qrow.get("recall_post_guard", 0.0) < QUANT_MIN_RECALL:
            failures.append(
                f"{name}: post-guard recall "
                f"{qrow.get('recall_post_guard', 0.0):.3f} < {QUANT_MIN_RECALL} "
                "— the full-precision gather-rerank guard is not restoring "
                "the quantized scan's recall"
            )
        speed = qrow.get("speedup_vs_f32", 0.0)
        if qrow.get("quantized_native", False):
            if speed <= 1.0:
                failures.append(
                    f"{name}: native quantized scan (speedup_vs_f32 "
                    f"{speed:.2f}x) is not faster than the f32 scan"
                )
        elif speed < QUANT_NON_NATIVE_SPEED_FLOOR:
            failures.append(
                f"{name}: non-native quantized scan (speedup_vs_f32 "
                f"{speed:.2f}x) fell below the "
                f"{QUANT_NON_NATIVE_SPEED_FLOOR}x plumbing floor"
            )

    # baseline drift, both directions: a baseline row no bench emits anymore
    # silently keeps gating thin air, and a bench row missing from the
    # baseline silently exempts itself from every baseline-relative gate
    for name in sorted(base_rows):
        if name not in rows:
            failures.append(
                f"{name}: present in the baseline but missing from the current "
                "run — its gates would silently vanish"
            )
    if base_rows:
        for name in sorted(rows):
            if name not in base_rows:
                failures.append(
                    f"{name}: emitted by the bench but missing from the "
                    "committed baseline — regenerate the baseline alongside "
                    "the change that added this row"
                )
    # machine factor: median throughput ratio over rows present in both.
    # When the document carries ``anchor.*`` rows (fixed pure-numpy work no
    # repo change can touch — bench_kernels writes one), the factor comes
    # from the anchors ALONE: otherwise a uniform real regression across
    # every gated row would read as "slower machine" and pass (the
    # query-paths file needs no anchor — its ungated beam rows already
    # anchor the median).
    all_ratios = {
        name: rows[name]["throughput_qps"] / base_rows[name]["throughput_qps"]
        for name in rows
        if name in base_rows
        and rows[name].get("throughput_qps") is not None
        and base_rows[name].get("throughput_qps")
    }
    anchor_ratios = [r for n, r in all_ratios.items() if n.startswith("anchor.")]
    ratios = sorted(anchor_ratios if anchor_ratios else all_ratios.values())
    factor = 1.0
    if ratios:
        mid = len(ratios) // 2
        factor = (
            ratios[mid] if len(ratios) % 2 else (ratios[mid - 1] + ratios[mid]) / 2.0
        )
    for name in sorted(rows):
        cur, base = rows[name], base_rows.get(name)
        if base is None:
            continue
        cur_qps, base_qps = cur.get("throughput_qps"), base.get("throughput_qps")
        if _throughput_gated(name) and cur_qps is not None and base_qps:
            budget = _regress_budget(name, max_regress)
            floor = (1.0 - budget) * base_qps * factor
            if cur_qps < floor:
                failures.append(
                    f"{name}: throughput {cur_qps:.1f} qps regressed "
                    f">{budget:.0%} below baseline {base_qps:.1f} qps "
                    f"(machine factor {factor:.2f} applied)"
                )
        cur_rec, base_rec = cur.get("recall"), base.get("recall")
        if cur_rec is not None and base_rec is not None:
            if cur_rec < base_rec - RECALL_EPS:
                failures.append(
                    f"{name}: recall {cur_rec:.4f} dropped below baseline "
                    f"{base_rec:.4f}"
                )
    return failures


def _load(path: str) -> dict:
    if os.path.exists(path) and os.path.getsize(path) == 0:
        raise ValueError("file is empty — the bench run crashed before writing it?")
    with open(path) as f:
        return json.load(f)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "current", nargs="+",
        help="JSON record(s) written by the benchmark run(s)",
    )
    ap.add_argument(
        "--baseline",
        action="append",
        default=None,
        help="committed baseline for the current file at the same position "
        "(repeatable; '' skips that file's baseline gates).  Default: "
        f"{DEFAULT_BASELINE_DIR}/<basename of the current file>",
    )
    ap.add_argument(
        "--max-regress", type=float, default=DEFAULT_MAX_REGRESS,
        help="tolerated fractional throughput drop vs baseline (default 0.20)",
    )
    args = ap.parse_args(argv)
    baselines = args.baseline
    if baselines is None:
        baselines = [
            os.path.join(DEFAULT_BASELINE_DIR, os.path.basename(p))
            for p in args.current
        ]
    if len(baselines) != len(args.current):
        print(
            f"check_bench: {len(args.current)} bench file(s) but "
            f"{len(baselines)} --baseline flag(s) — pass one per file "
            "('' to skip a file's baseline gates)",
            file=sys.stderr,
        )
        return 2
    failures: List[str] = []
    total_rows = 0
    base_notes: List[str] = []
    for cur_path, base_path in zip(args.current, baselines):
        try:
            current = _load(cur_path)
        except (OSError, ValueError) as e:
            print(f"check_bench: cannot read {cur_path}: {e}", file=sys.stderr)
            return 2
        if not current.get("rows"):
            # a crashed bench that still wrote an empty shell (or a stale
            # truncated file) must not green-light itself
            print(
                f"check_bench: {cur_path} contains no benchmark rows — the "
                "bench run did not complete",
                file=sys.stderr,
            )
            return 2
        baseline = None
        if base_path:
            try:
                baseline = _load(base_path)
            except (OSError, ValueError) as e:
                print(f"check_bench: cannot read baseline {base_path}: {e}",
                      file=sys.stderr)
                return 2
        failures.extend(check(current, baseline, max_regress=args.max_regress))
        total_rows += len(current.get("rows", {}))
        base_notes.append(base_path if baseline is not None else "(none)")
    base_note = ", ".join(base_notes)
    if failures:
        for f_msg in failures:
            print(f"BENCH-REGRESSION: {f_msg}")
        print(f"check_bench: {len(failures)} regression(s) across {total_rows} rows "
              f"(baseline: {base_note})")
        return 1
    print(f"check_bench: OK — {total_rows} rows within gates (baseline: {base_note})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
