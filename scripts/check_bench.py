#!/usr/bin/env python
"""Benchmark-regression gate for CI (scripts/ci.sh --bench).

Reads the machine-readable record a benchmark run writes (currently
``BENCH_query_paths.json`` from ``benchmarks/bench_query_paths.py``) and
fails with a readable report when the run regresses, replacing the ad-hoc
asserts that used to live inside the bench script:

Absolute gates (hold regardless of any baseline):
  - ``table2.batched``: per-query parity with sequential probes
    (``parity_ok``) and throughput strictly above the sequential path
    (``speedup > 1``);
  - ``table2.filtered``: recall vs the brute-force post-filter oracle
    >= 0.95, and zone-map pruning still reducing dispatched shard
    fragments (fewer fragments than the unfiltered batch, or whole shards
    pruned) on the high-selectivity predicate.

Baseline gates (vs the committed baseline, benchmarks/baselines/):
  - a THROUGHPUT_GATED row's ``throughput_qps`` dropping more than
    ``--max-regress`` (default 20%) below the baseline, after normalizing
    by the machine factor — the MEDIAN of cur/base throughput ratios
    across ALL rows.  The baseline was recorded on one machine and CI runs
    on another, so a uniform speed difference must divide out; a real
    regression changes one path's ratio and sticks out from the median.
    Only the filtered pipeline row is throughput-gated: its timing is
    masked-kernel-dominated and reproducible, while every beam-search-
    driven row (the table rows AND the batched row, which runs the same
    beam machinery) swings >2x with ambient load even best-of-N
    (measured live) — gating those on wall clock makes CI cry wolf.  The
    batched row is instead gated on its speedup ratio (batched vs
    sequential measured in the same window, so load cancels).  All rows
    still feed the machine factor and the recall gate.
  - any row present in the baseline but MISSING from the current run — a
    silently dropped row would otherwise un-gate itself.
  - ANY row's ``recall`` dropping below the baseline at all (recall is
    deterministic under the bench's fixed seeds, so any drop is a real
    behavior change, not timing noise).

Baseline update procedure: see the header of scripts/ci.sh.

Exit status: 0 = clean, 1 = regression(s) (each printed on its own
``BENCH-REGRESSION:`` line), 2 = bad invocation / unreadable input.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

DEFAULT_MAX_REGRESS = 0.20
RECALL_EPS = 1e-9  # float-representation slack only: ANY real drop fails
FILTERED_MIN_RECALL = 0.95
# rows whose wall-clock is stable enough to gate (see module docstring)
THROUGHPUT_GATED = ("table2.filtered",)


def check(
    current: dict,
    baseline: Optional[dict],
    max_regress: float = DEFAULT_MAX_REGRESS,
) -> List[str]:
    """Pure gate logic: returns a list of human-readable failures (empty =
    clean).  Split from main() so the unit tests can doctor JSON documents
    and assert specific injected regressions are caught."""
    failures: List[str] = []
    rows = current.get("rows", {})
    base_rows = (baseline or {}).get("rows", {})

    batched = rows.get("table2.batched")
    if batched is not None:
        if not batched.get("parity_ok", True):
            failures.append(
                "table2.batched: batched hits diverge from sequential probes"
            )
        if batched.get("speedup", 0.0) <= 1.0:
            failures.append(
                f"table2.batched: batched throughput "
                f"{batched.get('throughput_qps', 0.0):.1f} qps is not above the "
                f"sequential path {batched.get('seq_qps', 0.0):.1f} qps"
            )
    filtered = rows.get("table2.filtered")
    if filtered is not None:
        if filtered.get("recall", 0.0) < FILTERED_MIN_RECALL:
            failures.append(
                f"table2.filtered: recall vs oracle {filtered.get('recall', 0.0):.3f} "
                f"< {FILTERED_MIN_RECALL}"
            )
        if (
            filtered.get("probe_fragments", 0)
            >= filtered.get("unfiltered_fragments", 0)
            and filtered.get("shards_pruned", 0) == 0
        ):
            failures.append(
                "table2.filtered: zone-map pruning dispatched no fewer shard "
                f"fragments ({filtered.get('probe_fragments')} vs unfiltered "
                f"{filtered.get('unfiltered_fragments')}) on a high-selectivity "
                "predicate"
            )

    for name in sorted(base_rows):
        if name not in rows:
            failures.append(
                f"{name}: present in the baseline but missing from the current "
                "run — its gates would silently vanish"
            )
    # machine factor: median throughput ratio over rows present in both
    ratios = sorted(
        rows[name]["throughput_qps"] / base_rows[name]["throughput_qps"]
        for name in rows
        if name in base_rows
        and rows[name].get("throughput_qps") is not None
        and base_rows[name].get("throughput_qps")
    )
    factor = 1.0
    if ratios:
        mid = len(ratios) // 2
        factor = (
            ratios[mid] if len(ratios) % 2 else (ratios[mid - 1] + ratios[mid]) / 2.0
        )
    for name in sorted(rows):
        cur, base = rows[name], base_rows.get(name)
        if base is None:
            continue
        cur_qps, base_qps = cur.get("throughput_qps"), base.get("throughput_qps")
        if name in THROUGHPUT_GATED and cur_qps is not None and base_qps:
            floor = (1.0 - max_regress) * base_qps * factor
            if cur_qps < floor:
                failures.append(
                    f"{name}: throughput {cur_qps:.1f} qps regressed "
                    f">{max_regress:.0%} below baseline {base_qps:.1f} qps "
                    f"(machine factor {factor:.2f} applied)"
                )
        cur_rec, base_rec = cur.get("recall"), base.get("recall")
        if cur_rec is not None and base_rec is not None:
            if cur_rec < base_rec - RECALL_EPS:
                failures.append(
                    f"{name}: recall {cur_rec:.4f} dropped below baseline "
                    f"{base_rec:.4f}"
                )
    return failures


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="JSON written by the benchmark run")
    ap.add_argument(
        "--baseline",
        default="benchmarks/baselines/BENCH_query_paths.json",
        help="committed baseline to compare against ('' skips baseline gates)",
    )
    ap.add_argument(
        "--max-regress", type=float, default=DEFAULT_MAX_REGRESS,
        help="tolerated fractional throughput drop vs baseline (default 0.20)",
    )
    args = ap.parse_args(argv)
    try:
        current = _load(args.current)
    except (OSError, ValueError) as e:
        print(f"check_bench: cannot read {args.current}: {e}", file=sys.stderr)
        return 2
    baseline = None
    if args.baseline:
        try:
            baseline = _load(args.baseline)
        except (OSError, ValueError) as e:
            print(f"check_bench: cannot read baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return 2
    failures = check(current, baseline, max_regress=args.max_regress)
    n_rows = len(current.get("rows", {}))
    base_note = args.baseline if baseline is not None else "(none)"
    if failures:
        for f_msg in failures:
            print(f"BENCH-REGRESSION: {f_msg}")
        print(f"check_bench: {len(failures)} regression(s) across {n_rows} rows "
              f"(baseline: {base_note})")
        return 1
    print(f"check_bench: OK — {n_rows} rows within gates (baseline: {base_note})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
